//! Per-request causal cost ledger.
//!
//! The live plane (`telemetry::live`) answers fleet questions — "what
//! is TTFT p99 *right now*" — but only in aggregate. This module is
//! the per-request counterpart: a [`RequestLedger`] follows each
//! request across router → batcher admission → scheduler ticks →
//! kvpool/shard events and records
//!
//! * a typed causal event chain ([`LedgerEvent`]: routed, enqueued,
//!   admitted, prefill chunks, decode ticks, preemptions/resumes,
//!   shard spills, completion), each stamped with the driving clock,
//! * per-phase wall time split into compute vs. idle buckets (the
//!   request-granular analogue of `attribution.rs` gap folding:
//!   queueing, KV-capacity wait, preempted time, batch-interference
//!   idle),
//! * pages held over time (page-seconds — the KV-occupancy cost the
//!   fairness/QoS tier charges against), and
//! * via [`energy`], a modeled Joule estimate from `perfmodel`'s
//!   roofline FLOPs-and-bytes accounting (prefill vs. decode vs. idle
//!   power states, per model family).
//!
//! [`explain`] builds the tail-latency explainer on top: for any
//! quantile band it decomposes slow requests into queueing /
//! capacity-wait / preemption / spill / sync contributions and names
//! the dominant cause (`mmserve explain`).
//!
//! The ledger follows the live plane's contracts exactly: it is pure
//! observation (attaching it never changes scheduling decisions,
//! clocks, or outputs — CI replays with and without it and fails on
//! any `sim_time` delta), and [`RequestLedger::off`] costs one
//! relaxed atomic load per would-be hook (asserted by
//! `benches/telemetry_overhead.rs`).

pub mod energy;
pub mod explain;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::substrate::json::Json;

/// One step in a request's causal chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LedgerEvent {
    /// Router picked a replica for this request.
    Routed { replica: u32 },
    /// Delivered into a worker's arrival queue.
    Enqueued,
    /// Batcher admitted the first prefill chunk (slot + pages held).
    Admitted { tokens: usize },
    /// A continuation prefill chunk was committed.
    PrefillChunk { tokens: usize },
    /// First decoded token emitted (the TTFT point).
    FirstToken,
    /// The request decoded one token this scheduler tick.
    DecodeTick,
    /// Evicted to reclaim pages (recompute on re-admission).
    Preempted,
    /// Re-admitted after a preemption.
    Resumed,
    /// A page allocation spilled off the request's home shard.
    Spill,
    /// The request's KV moved over the priced transfer fabric (a swap
    /// direction over the host link, or a disaggregated prefill→decode
    /// handoff over the inter-replica link).
    Transfer { bytes: u64 },
    /// All tokens decoded; slot released.
    Completed { decoded: u64 },
}

impl LedgerEvent {
    pub fn label(&self) -> &'static str {
        match self {
            LedgerEvent::Routed { .. } => "routed",
            LedgerEvent::Enqueued => "enqueued",
            LedgerEvent::Admitted { .. } => "admitted",
            LedgerEvent::PrefillChunk { .. } => "prefill-chunk",
            LedgerEvent::FirstToken => "first-token",
            LedgerEvent::DecodeTick => "decode-tick",
            LedgerEvent::Preempted => "preempted",
            LedgerEvent::Resumed => "resumed",
            LedgerEvent::Spill => "shard-spill",
            LedgerEvent::Transfer { .. } => "transfer",
            LedgerEvent::Completed { .. } => "completed",
        }
    }
}

/// An event stamped with the driving clock (simulated seconds in the
/// replay drivers, wall seconds on the real serving path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimedEvent {
    pub t: f64,
    pub ev: LedgerEvent,
}

/// Everything the ledger accumulated for one request. Time buckets
/// partition the request's resident wall time: `queue_time` (waiting,
/// pool not blocked), `capacity_wait_time` (waiting while admission
/// was blocked on pages), `preempted_time` (evicted, awaiting
/// re-admission), `prefill_compute`/`decode_compute` (this request's
/// own share of dispatched work), and `interference_idle` (scheduled
/// in a tick but idle behind co-batched work — the request-level
/// "sync" bucket, the per-request analogue of the attribution pass's
/// PrefillStall/Other gaps).
#[derive(Debug, Clone, Default)]
pub struct RequestRecord {
    pub id: u64,
    pub tenant: String,
    pub replica: u32,
    pub prompt_len: usize,
    /// Causal chain in arrival order.
    pub events: Vec<TimedEvent>,
    pub enqueued_at: f64,
    pub first_token_at: Option<f64>,
    pub completed_at: Option<f64>,
    /// Tokens decoded so far.
    pub decoded: u64,
    /// Prompt tokens actually prefilled, *including* recompute after
    /// preemptions — this is the work (and energy) really spent, which
    /// can exceed `prompt_len`.
    pub prefilled_tokens: usize,
    pub preemptions: u64,
    pub spills: u64,
    /// Modeled cost of this request's cross-shard spills when a priced
    /// fabric sized them by actual bytes over NVLink (0.0 unpriced —
    /// the explainer falls back to its flat per-spill constant).
    pub spill_cost: f64,
    /// Modeled time the request's KV spent moving over the transfer
    /// fabric (swap round trips, disaggregated handoffs). A swap
    /// converts what would be `preempted_time` + re-prefill compute
    /// into this bucket.
    pub transfer_time: f64,
    /// Bytes of this request's KV moved over the fabric.
    pub transfer_bytes: u64,
    pub queue_time: f64,
    pub capacity_wait_time: f64,
    pub preempted_time: f64,
    pub prefill_compute: f64,
    pub decode_compute: f64,
    pub interference_idle: f64,
    /// ∫ pages-held dt — KV occupancy cost.
    pub page_seconds: f64,
    /// Per-token time-between-tokens samples (parity source for the
    /// live plane's TBT sketch).
    pub tbt: Vec<f64>,
    /// A preemption is open until the next admission closes it.
    open_preempt: bool,
}

impl RequestRecord {
    /// Time to first token (None until one is emitted). Matches the
    /// live plane's definition: measured from the *latest* enqueue, so
    /// a request re-delivered after a replica crash restarts its
    /// clock on both planes.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| t - self.enqueued_at)
    }

    /// End-to-end latency (None until completed).
    pub fn latency(&self) -> Option<f64> {
        self.completed_at.map(|t| t - self.enqueued_at)
    }

    /// Total attributed idle time (everything that is neither this
    /// request's own compute nor unaccounted).
    pub fn idle_total(&self) -> f64 {
        self.queue_time
            + self.capacity_wait_time
            + self.preempted_time
            + self.interference_idle
    }

    /// One JSONL line for `--ledger-out`.
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("t".to_string(), Json::Num(e.t)),
                    ("ev".to_string(),
                     Json::Str(e.ev.label().to_string())),
                ];
                match e.ev {
                    LedgerEvent::Routed { replica } => fields.push((
                        "replica".to_string(),
                        Json::Num(replica as f64),
                    )),
                    LedgerEvent::Admitted { tokens }
                    | LedgerEvent::PrefillChunk { tokens } => fields
                        .push((
                            "tokens".to_string(),
                            Json::Num(tokens as f64),
                        )),
                    LedgerEvent::Transfer { bytes } => fields.push((
                        "bytes".to_string(),
                        Json::Num(bytes as f64),
                    )),
                    LedgerEvent::Completed { decoded } => fields.push((
                        "decoded".to_string(),
                        Json::Num(decoded as f64),
                    )),
                    _ => {}
                }
                Json::from_obj(fields)
            })
            .collect();
        let opt = |v: Option<f64>| match v {
            Some(x) => Json::Num(x),
            None => Json::Null,
        };
        Json::from_obj(vec![
            ("id".to_string(), Json::Num(self.id as f64)),
            ("tenant".to_string(), Json::Str(self.tenant.clone())),
            ("replica".to_string(), Json::Num(self.replica as f64)),
            ("prompt_len".to_string(),
             Json::Num(self.prompt_len as f64)),
            ("decoded".to_string(), Json::Num(self.decoded as f64)),
            ("prefilled_tokens".to_string(),
             Json::Num(self.prefilled_tokens as f64)),
            ("enqueued_at".to_string(), Json::Num(self.enqueued_at)),
            ("ttft".to_string(), opt(self.ttft())),
            ("latency".to_string(), opt(self.latency())),
            ("preemptions".to_string(),
             Json::Num(self.preemptions as f64)),
            ("spills".to_string(), Json::Num(self.spills as f64)),
            ("spill_cost".to_string(), Json::Num(self.spill_cost)),
            ("transfer_time".to_string(),
             Json::Num(self.transfer_time)),
            ("transfer_bytes".to_string(),
             Json::Num(self.transfer_bytes as f64)),
            ("queue_time".to_string(), Json::Num(self.queue_time)),
            ("capacity_wait_time".to_string(),
             Json::Num(self.capacity_wait_time)),
            ("preempted_time".to_string(),
             Json::Num(self.preempted_time)),
            ("prefill_compute".to_string(),
             Json::Num(self.prefill_compute)),
            ("decode_compute".to_string(),
             Json::Num(self.decode_compute)),
            ("interference_idle".to_string(),
             Json::Num(self.interference_idle)),
            ("page_seconds".to_string(),
             Json::Num(self.page_seconds)),
            ("events".to_string(), Json::Arr(events)),
        ])
    }
}

/// Per-tick bulk charges: which requests waited (and why), which were
/// fed prefill compute, and how many pages each resident request held
/// across the tick. Passed by the driver once per tick so the ledger
/// takes one lock, not one per request.
#[derive(Debug, Default)]
pub struct TickCharges<'a> {
    /// Tick duration on the driving clock.
    pub dt: f64,
    /// Admission was blocked on pool capacity this tick (waiting
    /// requests charge `capacity_wait_time` instead of `queue_time`).
    pub blocked_on_capacity: bool,
    /// Requests staged/waiting for admission.
    pub waiting: &'a [u64],
    /// `(request, own prefill compute this tick)`.
    pub prefill: &'a [(u64, f64)],
    /// `(request, pages held)` for every resident request.
    pub pages: &'a [(u64, u64)],
}

#[derive(Debug, Default)]
struct LedgerCore {
    enabled: AtomicBool,
    state: Mutex<BTreeMap<u64, RequestRecord>>,
}

/// Cloneable per-request ledger handle (`Send + Sync`). Disabled mode
/// is the tracer/live-plane contract: every hook is one relaxed
/// atomic load and nothing else.
#[derive(Debug, Clone, Default)]
pub struct RequestLedger {
    core: Arc<LedgerCore>,
}

impl RequestLedger {
    /// An enabled ledger.
    pub fn new() -> Self {
        let led = RequestLedger::default();
        led.core.enabled.store(true, Ordering::Relaxed);
        led
    }

    /// A disabled ledger: every hook is one relaxed atomic load.
    pub fn off() -> Self {
        RequestLedger::default()
    }

    pub fn is_enabled(&self) -> bool {
        self.core.enabled.load(Ordering::Relaxed)
    }

    /// A panicking worker must degrade the ledger, never take down
    /// the publisher: recover the poisoned map.
    fn lock(&self) -> MutexGuard<'_, BTreeMap<u64, RequestRecord>> {
        self.core
            .state
            .lock()
            .unwrap_or_else(|poison| poison.into_inner())
    }

    fn with_record(&self, id: u64, f: impl FnOnce(&mut RequestRecord)) {
        if !self.is_enabled() {
            return;
        }
        let mut st = self.lock();
        let rec = st.entry(id).or_insert_with(|| RequestRecord {
            id,
            ..RequestRecord::default()
        });
        f(rec);
    }

    /// Router picked `replica` for this request.
    pub fn routed(&self, id: u64, replica: u32, now: f64) {
        self.with_record(id, |rec| {
            rec.replica = replica;
            rec.events.push(TimedEvent {
                t: now,
                ev: LedgerEvent::Routed { replica },
            });
        });
    }

    /// Delivered into a worker's queue. Re-delivery (after a replica
    /// crash) restarts the request's clock — matching the live
    /// plane's TTFT definition — but keeps the accumulated buckets
    /// and event chain: the cost was really paid.
    pub fn enqueued(
        &self,
        id: u64,
        replica: u32,
        tenant: &str,
        prompt_len: usize,
        now: f64,
    ) {
        self.with_record(id, |rec| {
            rec.replica = replica;
            rec.tenant = tenant.to_string();
            rec.prompt_len = prompt_len;
            rec.enqueued_at = now;
            rec.first_token_at = None;
            rec.events
                .push(TimedEvent { t: now, ev: LedgerEvent::Enqueued });
        });
    }

    /// First prefill chunk admitted (`tokens` committed). Closes an
    /// open preemption (this is the resume point).
    pub fn admitted(&self, id: u64, tokens: usize, now: f64) {
        self.with_record(id, |rec| {
            if rec.open_preempt {
                rec.open_preempt = false;
                rec.events.push(TimedEvent {
                    t: now,
                    ev: LedgerEvent::Resumed,
                });
            }
            rec.prefilled_tokens += tokens;
            rec.events.push(TimedEvent {
                t: now,
                ev: LedgerEvent::Admitted { tokens },
            });
        });
    }

    /// A continuation prefill chunk was committed.
    pub fn prefill_chunk(&self, id: u64, tokens: usize, now: f64) {
        self.with_record(id, |rec| {
            rec.prefilled_tokens += tokens;
            rec.events.push(TimedEvent {
                t: now,
                ev: LedgerEvent::PrefillChunk { tokens },
            });
        });
    }

    /// First token emitted (idempotent: only the first call per
    /// enqueue records the TTFT point).
    pub fn first_token(&self, id: u64, now: f64) {
        self.with_record(id, |rec| {
            if rec.first_token_at.is_none() {
                rec.first_token_at = Some(now);
                rec.events.push(TimedEvent {
                    t: now,
                    ev: LedgerEvent::FirstToken,
                });
            }
        });
    }

    /// One token decoded: `tbt` is the tick's time-between-tokens
    /// sample (identical to what the live plane's sketch observes),
    /// `compute` this request's own share of the tick's dispatch —
    /// the remainder is batch-interference idle.
    pub fn decoded(&self, id: u64, now: f64, tbt: f64, compute: f64) {
        self.with_record(id, |rec| {
            rec.decoded += 1;
            rec.tbt.push(tbt);
            rec.decode_compute += compute;
            rec.interference_idle += (tbt - compute).max(0.0);
            rec.events
                .push(TimedEvent { t: now, ev: LedgerEvent::DecodeTick });
        });
    }

    /// Evicted to reclaim pages; open until the next `admitted`.
    pub fn preempted(&self, id: u64, now: f64) {
        self.with_record(id, |rec| {
            rec.preemptions += 1;
            rec.open_preempt = true;
            rec.events
                .push(TimedEvent { t: now, ev: LedgerEvent::Preempted });
        });
    }

    /// A page allocation spilled off the request's home shard. `cost`
    /// is the fabric-priced NVLink gather for the spilled page (0.0
    /// when no fabric prices it — the explainer then weighs the spill
    /// with its flat per-spill constant).
    pub fn spill(&self, id: u64, cost: f64, now: f64) {
        self.with_record(id, |rec| {
            rec.spills += 1;
            rec.spill_cost += cost;
            rec.events
                .push(TimedEvent { t: now, ev: LedgerEvent::Spill });
        });
    }

    /// The request's KV moved `bytes` over the priced fabric at
    /// modeled cost `cost` (one swap direction or one disaggregated
    /// handoff — a swap round trip is two calls). Deliberately does
    /// not close an open preemption: a swapped victim is parked in a
    /// host buffer, not re-prefilled, and its cost lives here instead
    /// of in `preempted_time`.
    pub fn transfer(&self, id: u64, bytes: u64, cost: f64, now: f64) {
        self.with_record(id, |rec| {
            rec.transfer_time += cost;
            rec.transfer_bytes += bytes;
            rec.events.push(TimedEvent {
                t: now,
                ev: LedgerEvent::Transfer { bytes },
            });
        });
    }

    /// All tokens decoded; the request left the worker.
    pub fn completed(&self, id: u64, now: f64) {
        self.with_record(id, |rec| {
            rec.completed_at = Some(now);
            let decoded = rec.decoded;
            rec.events.push(TimedEvent {
                t: now,
                ev: LedgerEvent::Completed { decoded },
            });
        });
    }

    /// Bulk per-tick charges (waiting buckets, prefill compute +
    /// interference, page-seconds). One lock per tick.
    pub fn charge_tick(&self, c: &TickCharges<'_>) {
        if !self.is_enabled() {
            return;
        }
        let mut st = self.lock();
        for &id in c.waiting {
            if let Some(rec) = st.get_mut(&id) {
                if rec.open_preempt {
                    rec.preempted_time += c.dt;
                } else if c.blocked_on_capacity {
                    rec.capacity_wait_time += c.dt;
                } else {
                    rec.queue_time += c.dt;
                }
            }
        }
        for &(id, own) in c.prefill {
            if let Some(rec) = st.get_mut(&id) {
                rec.prefill_compute += own;
                rec.interference_idle += (c.dt - own).max(0.0);
            }
        }
        for &(id, pages) in c.pages {
            if let Some(rec) = st.get_mut(&id) {
                rec.page_seconds += pages as f64 * c.dt;
            }
        }
    }

    /// Point-in-time copy of every record, in request-id order.
    pub fn snapshot(&self) -> LedgerSnapshot {
        if !self.is_enabled() {
            return LedgerSnapshot::default();
        }
        LedgerSnapshot {
            requests: self.lock().values().cloned().collect(),
        }
    }
}

/// Everything the ledger knew at one instant (request-id order).
#[derive(Debug, Clone, Default)]
pub struct LedgerSnapshot {
    pub requests: Vec<RequestRecord>,
}

impl LedgerSnapshot {
    pub fn get(&self, id: u64) -> Option<&RequestRecord> {
        self.requests.iter().find(|r| r.id == id)
    }

    /// Records that reached completion.
    pub fn completed(&self) -> Vec<&RequestRecord> {
        self.requests
            .iter()
            .filter(|r| r.completed_at.is_some())
            .collect()
    }

    /// All per-request TTFT samples (parity source for the live
    /// plane's TTFT sketch).
    pub fn ttft_values(&self) -> Vec<f64> {
        self.requests.iter().filter_map(|r| r.ttft()).collect()
    }

    /// All per-token TBT samples.
    pub fn tbt_values(&self) -> Vec<f64> {
        self.requests
            .iter()
            .flat_map(|r| r.tbt.iter().copied())
            .collect()
    }

    /// JSONL dump, one request per line (`--ledger-out`).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for rec in &self.requests {
            out.push_str(&rec.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lifecycle(led: &RequestLedger) {
        led.routed(1, 2, 0.0);
        led.enqueued(1, 2, "tenant-a", 8, 0.0);
        led.charge_tick(&TickCharges {
            dt: 1.0,
            blocked_on_capacity: false,
            waiting: &[1],
            prefill: &[],
            pages: &[],
        });
        led.admitted(1, 8, 1.0);
        led.charge_tick(&TickCharges {
            dt: 0.4,
            blocked_on_capacity: false,
            waiting: &[],
            prefill: &[(1, 0.4)],
            pages: &[(1, 1)],
        });
        led.first_token(1, 1.4);
        led.decoded(1, 1.4, 0.5, 0.25);
        led.preempted(1, 2.0);
        led.charge_tick(&TickCharges {
            dt: 0.5,
            blocked_on_capacity: true,
            waiting: &[1],
            prefill: &[],
            pages: &[],
        });
        led.admitted(1, 8, 2.5);
        led.decoded(1, 3.0, 0.5, 0.5);
        led.completed(1, 3.0);
    }

    #[test]
    fn disabled_ledger_is_inert() {
        let led = RequestLedger::off();
        lifecycle(&led);
        assert!(!led.is_enabled());
        assert!(led.snapshot().requests.is_empty());
    }

    #[test]
    fn lifecycle_accumulates_buckets_and_events() {
        let led = RequestLedger::new();
        lifecycle(&led);
        let snap = led.snapshot();
        let rec = snap.get(1).expect("record exists");
        assert_eq!(rec.tenant, "tenant-a");
        assert_eq!(rec.replica, 2);
        assert_eq!(rec.decoded, 2);
        // Recompute after the preemption counts twice.
        assert_eq!(rec.prefilled_tokens, 16);
        assert_eq!(rec.preemptions, 1);
        assert!((rec.queue_time - 1.0).abs() < 1e-9);
        // The open preemption wins over the capacity-blocked flag.
        assert!((rec.preempted_time - 0.5).abs() < 1e-9);
        assert!((rec.capacity_wait_time).abs() < 1e-9);
        assert!((rec.prefill_compute - 0.4).abs() < 1e-9);
        assert!((rec.decode_compute - 0.75).abs() < 1e-9);
        assert!((rec.interference_idle - 0.25).abs() < 1e-9);
        assert!((rec.page_seconds - 0.4).abs() < 1e-9);
        assert_eq!(rec.ttft(), Some(1.4));
        assert_eq!(rec.latency(), Some(3.0));
        assert_eq!(rec.tbt, vec![0.5, 0.5]);
        // Causal chain: routed → enqueued → admitted → first-token →
        // decode → preempted → resumed → admitted → decode → done.
        let labels: Vec<&str> =
            rec.events.iter().map(|e| e.ev.label()).collect();
        assert_eq!(
            labels,
            vec![
                "routed", "enqueued", "admitted", "first-token",
                "decode-tick", "preempted", "resumed", "admitted",
                "decode-tick", "completed",
            ]
        );
    }

    #[test]
    fn redelivery_restarts_the_clock_but_keeps_costs() {
        let led = RequestLedger::new();
        led.enqueued(7, 0, "-", 4, 0.0);
        led.charge_tick(&TickCharges {
            dt: 2.0,
            blocked_on_capacity: false,
            waiting: &[7],
            prefill: &[],
            pages: &[],
        });
        led.admitted(7, 4, 2.0);
        led.first_token(7, 3.0);
        // Replica crash: re-routed and re-delivered at t=5 on the
        // surviving worker's clock.
        led.enqueued(7, 1, "-", 4, 5.0);
        led.admitted(7, 4, 6.0);
        led.first_token(7, 7.5);
        led.completed(7, 8.0);
        let snap = led.snapshot();
        let rec = snap.get(7).unwrap();
        assert_eq!(rec.replica, 1);
        assert_eq!(rec.ttft(), Some(2.5), "TTFT restarts on re-enqueue");
        assert!((rec.queue_time - 2.0).abs() < 1e-9, "costs survive");
        assert_eq!(rec.prefilled_tokens, 8);
    }

    #[test]
    fn jsonl_roundtrips_and_snapshot_helpers_filter() {
        let led = RequestLedger::new();
        lifecycle(&led);
        led.enqueued(2, 0, "tenant-b", 3, 0.0); // never completes
        let snap = led.snapshot();
        assert_eq!(snap.requests.len(), 2);
        assert_eq!(snap.completed().len(), 1);
        assert_eq!(snap.ttft_values().len(), 1);
        assert_eq!(snap.tbt_values().len(), 2);
        let jsonl = snap.to_jsonl();
        assert_eq!(jsonl.lines().count(), 2);
        for line in jsonl.lines() {
            let doc = Json::parse(line).unwrap_or_else(|e| {
                panic!("invalid ledger JSONL {line:?}: {e}")
            });
            assert!(doc.get("id").and_then(Json::as_f64).is_some());
            assert!(doc.get("events").and_then(Json::as_arr).is_some());
        }
        let one = Json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(one.get("latency").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn transfer_accumulates_bytes_and_cost() {
        let led = RequestLedger::new();
        led.enqueued(3, 0, "-", 4, 0.0);
        // A swap round trip: out at t=1, back in at t=2.
        led.transfer(3, 1024, 0.25, 1.0);
        led.transfer(3, 1024, 0.25, 2.0);
        let snap = led.snapshot();
        let rec = snap.get(3).unwrap();
        assert_eq!(rec.transfer_bytes, 2048);
        assert!((rec.transfer_time - 0.5).abs() < 1e-9);
        let labels: Vec<&str> =
            rec.events.iter().map(|e| e.ev.label()).collect();
        assert_eq!(labels, vec!["enqueued", "transfer", "transfer"]);
        let doc = Json::parse(&rec.to_json().to_string()).unwrap();
        assert_eq!(doc.get("transfer_bytes").and_then(Json::as_f64),
                   Some(2048.0));
        assert_eq!(doc.get("transfer_time").and_then(Json::as_f64),
                   Some(0.5));
        let evs = doc.get("events").and_then(Json::as_arr).unwrap();
        assert_eq!(evs[1].get("bytes").and_then(Json::as_f64),
                   Some(1024.0));
    }

    #[test]
    fn charges_for_unknown_requests_are_dropped() {
        let led = RequestLedger::new();
        led.charge_tick(&TickCharges {
            dt: 1.0,
            blocked_on_capacity: false,
            waiting: &[99],
            prefill: &[(99, 1.0)],
            pages: &[(99, 4)],
        });
        assert!(led.snapshot().requests.is_empty());
    }
}
