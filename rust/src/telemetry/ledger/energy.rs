//! Modeled per-request energy attribution (Joules, tokens-per-Joule).
//!
//! The paper's characterization and the modality-inflation follow-up
//! both argue that serving cost is phase-dependent: prefill runs near
//! the compute roof (power ≈ TDP), decode is memory-bound (the device
//! clocks down — we model it as a fixed fraction of TDP), and idle
//! time still burns static power. Nothing here is measured: Joules
//! are derived from `perfmodel`'s roofline FLOPs-and-bytes walks
//! ([`crate::perfmodel::ops`]) costed on a device spec, multiplied by
//! datasheet power numbers — deterministic, so CI can gate
//! tokens-per-Joule like any other replay metric.
//!
//! Phase energies per request (from its [`RequestRecord`]):
//!
//! * prefill: `cost_walk(decoder_prefill(prefilled_tokens)) × TDP` —
//!   recomputed prefill after preemption is charged again, because
//!   that energy was really spent;
//! * decode: per-step roofline cost sampled over the growing context
//!   (same 8-point rule as `perfmodel::latency`) `× TDP ×`
//!   [`DECODE_POWER_FRAC`];
//! * idle: the ledger's idle buckets (queue + capacity wait +
//!   preempted + interference) are simulated-clock units, scaled into
//!   modeled seconds by the request's own modeled-busy / sim-busy
//!   ratio, `× idle_w`.

use crate::perfmodel::configs::{
    PaperDecoder, CHAMELEON_34B, CHAMELEON_7B, LLAMA_34B, LLAMA_7B,
};
use crate::perfmodel::device::DeviceSpec;
use crate::perfmodel::levers::cost_walk;
use crate::perfmodel::ops::{
    decoder_decode_step, decoder_prefill, AttnKind, LinearKind,
};

use std::collections::BTreeMap;

use super::{LedgerSnapshot, RequestRecord};

/// Datasheet power numbers for a device (board power, not fitted).
#[derive(Debug, Clone, Copy)]
pub struct PowerSpec {
    pub name: &'static str,
    /// Board TDP, watts (compute-bound phases run here).
    pub tdp_w: f64,
    /// Static/idle draw, watts.
    pub idle_w: f64,
}

/// NVIDIA A100-SXM4-80GB.
pub const A100_POWER: PowerSpec =
    PowerSpec { name: "A100", tdp_w: 400.0, idle_w: 55.0 };

/// NVIDIA H100-SXM5-80GB.
pub const H100_POWER: PowerSpec =
    PowerSpec { name: "H100", tdp_w: 700.0, idle_w: 70.0 };

/// Memory-bound decode draws well under TDP (the device is waiting on
/// HBM, not the tensor cores); 0.65 matches published LLM-decode
/// board-power measurements on Ampere/Hopper parts.
pub const DECODE_POWER_FRAC: f64 = 0.65;

/// The paper's decoder families (`perfmodel::configs` presets) the
/// energy model can attribute against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelFamily {
    Llama7b,
    Llama34b,
    Chameleon7b,
    Chameleon34b,
}

impl ModelFamily {
    pub const ALL: [ModelFamily; 4] = [
        ModelFamily::Llama7b,
        ModelFamily::Llama34b,
        ModelFamily::Chameleon7b,
        ModelFamily::Chameleon34b,
    ];

    pub fn cfg(self) -> &'static PaperDecoder {
        match self {
            ModelFamily::Llama7b => &LLAMA_7B,
            ModelFamily::Llama34b => &LLAMA_34B,
            ModelFamily::Chameleon7b => &CHAMELEON_7B,
            ModelFamily::Chameleon34b => &CHAMELEON_34B,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ModelFamily::Llama7b => "llama-7b",
            ModelFamily::Llama34b => "llama-34b",
            ModelFamily::Chameleon7b => "chameleon-7b",
            ModelFamily::Chameleon34b => "chameleon-34b",
        }
    }

    pub fn parse(s: &str) -> Option<ModelFamily> {
        ModelFamily::ALL
            .into_iter()
            .find(|f| f.as_str().eq_ignore_ascii_case(s))
    }
}

/// Joule attribution for one request (or an aggregate of requests).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    pub prefill_j: f64,
    pub decode_j: f64,
    pub idle_j: f64,
    pub tokens: u64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.prefill_j + self.decode_j + self.idle_j
    }

    /// The QoS-tier efficiency metric (0 when no energy attributed).
    pub fn tokens_per_joule(&self) -> f64 {
        let total = self.total_j();
        if total <= 0.0 { 0.0 } else { self.tokens as f64 / total }
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.prefill_j += other.prefill_j;
        self.decode_j += other.decode_j;
        self.idle_j += other.idle_j;
        self.tokens += other.tokens;
    }
}

/// Roofline energy model: a model family costed on a device spec with
/// that device's power numbers.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    pub family: ModelFamily,
    pub device: &'static DeviceSpec,
    pub power: PowerSpec,
}

impl EnergyModel {
    pub fn new(
        family: ModelFamily,
        device: &'static DeviceSpec,
    ) -> EnergyModel {
        let power = if device.name.eq_ignore_ascii_case(H100_POWER.name)
        {
            H100_POWER
        } else {
            A100_POWER
        };
        EnergyModel { family, device, power }
    }

    /// Lookup by device name (`a100`/`h100`, case-insensitive).
    pub fn by_device_name(
        family: ModelFamily,
        device: &str,
    ) -> Option<EnergyModel> {
        DeviceSpec::by_name(device).map(|d| EnergyModel::new(family, d))
    }

    /// Modeled busy seconds to prefill `tokens` at batch 1 (graph
    /// mode, flash attention — the optimized serving configuration).
    pub fn prefill_secs(&self, tokens: usize) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        let walk = decoder_prefill(
            self.family.cfg(),
            1,
            tokens,
            AttnKind::Flash,
            LinearKind::F32,
        );
        cost_walk(&walk, self.device, true).0
    }

    /// Modeled busy seconds to decode `steps` tokens from a
    /// `prompt_len` prompt: the per-step roofline cost sampled over
    /// the growing context, same 8-point rule as
    /// `perfmodel::latency::task_cost`.
    pub fn decode_secs(&self, prompt_len: usize, steps: u64) -> f64 {
        if steps == 0 {
            return 0.0;
        }
        let steps = steps as usize;
        let samples = 8.min(steps);
        let mut per_step = 0.0;
        for i in 0..samples {
            let ctx = prompt_len + (i + 1) * steps / samples;
            let walk = decoder_decode_step(
                self.family.cfg(),
                1,
                ctx.max(1),
                AttnKind::Flash,
                LinearKind::F32,
            );
            per_step += cost_walk(&walk, self.device, true).0;
        }
        per_step / samples as f64 * steps as f64
    }

    /// Attribute one request's Joules across power states.
    pub fn request_energy(&self, rec: &RequestRecord)
                          -> EnergyBreakdown {
        let pre = self.prefill_secs(rec.prefilled_tokens);
        let dec = self.decode_secs(rec.prompt_len, rec.decoded);
        // The ledger's buckets are simulated-clock units; the
        // request's own modeled-busy / sim-busy ratio converts its
        // idle share into modeled seconds on the same scale.
        let busy_sim = rec.prefill_compute + rec.decode_compute;
        let scale =
            if busy_sim > 0.0 { (pre + dec) / busy_sim } else { 0.0 };
        EnergyBreakdown {
            prefill_j: pre * self.power.tdp_w,
            decode_j: dec * self.power.tdp_w * DECODE_POWER_FRAC,
            idle_j: rec.idle_total() * scale * self.power.idle_w,
            tokens: rec.decoded,
        }
    }

    /// Aggregate Joules over every request in the snapshot.
    pub fn fleet_energy(&self, snap: &LedgerSnapshot)
                        -> EnergyBreakdown {
        let mut out = EnergyBreakdown::default();
        for rec in &snap.requests {
            out.add(&self.request_energy(rec));
        }
        out
    }

    /// Per-tenant Joule aggregation, sorted by tenant (the
    /// `mmserve stats` energy columns).
    pub fn energy_by_tenant(
        &self,
        snap: &LedgerSnapshot,
    ) -> Vec<(String, EnergyBreakdown)> {
        let mut by: BTreeMap<String, EnergyBreakdown> = BTreeMap::new();
        for rec in &snap.requests {
            by.entry(rec.tenant.clone())
                .or_default()
                .add(&self.request_energy(rec));
        }
        by.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::RequestLedger;
    use super::*;
    use crate::perfmodel::device::{A100, H100};

    fn sample_record() -> RequestRecord {
        let led = RequestLedger::new();
        led.enqueued(1, 0, "t0", 64, 0.0);
        led.admitted(1, 64, 1.0);
        for i in 0..32 {
            led.decoded(1, 1.0 + i as f64, 1.0, 0.5);
        }
        led.completed(1, 33.0);
        led.snapshot().get(1).cloned().unwrap()
    }

    #[test]
    fn family_parse_roundtrips() {
        for f in ModelFamily::ALL {
            assert_eq!(ModelFamily::parse(f.as_str()), Some(f));
        }
        assert_eq!(ModelFamily::parse("LLAMA-7B"),
                   Some(ModelFamily::Llama7b));
        assert!(ModelFamily::parse("gpt-5").is_none());
    }

    #[test]
    fn device_name_picks_power_spec() {
        let a = EnergyModel::by_device_name(ModelFamily::Llama7b,
                                            "a100")
            .unwrap();
        let h = EnergyModel::by_device_name(ModelFamily::Llama7b,
                                            "H100")
            .unwrap();
        assert_eq!(a.power.tdp_w, A100_POWER.tdp_w);
        assert_eq!(h.power.tdp_w, H100_POWER.tdp_w);
        assert!(EnergyModel::by_device_name(ModelFamily::Llama7b,
                                            "tpu")
            .is_none());
    }

    #[test]
    fn bigger_model_burns_more_joules() {
        let rec = sample_record();
        let small =
            EnergyModel::new(ModelFamily::Llama7b, &A100)
                .request_energy(&rec);
        let big =
            EnergyModel::new(ModelFamily::Llama34b, &A100)
                .request_energy(&rec);
        assert!(small.total_j() > 0.0);
        assert!(big.total_j() > small.total_j());
        assert!(big.tokens_per_joule() < small.tokens_per_joule());
    }

    #[test]
    fn phases_scale_with_work_and_idle_follows_buckets() {
        let m = EnergyModel::new(ModelFamily::Llama7b, &A100);
        let rec = sample_record();
        let e = m.request_energy(&rec);
        assert!(e.prefill_j > 0.0 && e.decode_j > 0.0);
        assert!(e.idle_j > 0.0, "interference idle draws static power");
        assert_eq!(e.tokens, 32);
        assert!(e.tokens_per_joule() > 0.0);
        // Doubling decode work increases decode energy.
        let mut longer = rec.clone();
        longer.decoded = 64;
        assert!(m.request_energy(&longer).decode_j > e.decode_j);
        // An empty record attributes nothing.
        let empty = RequestRecord::default();
        assert_eq!(m.request_energy(&empty).total_j(), 0.0);
    }

    #[test]
    fn h100_finishes_faster_but_draws_more() {
        let rec = sample_record();
        let a = EnergyModel::new(ModelFamily::Llama7b, &A100);
        let h = EnergyModel::new(ModelFamily::Llama7b, &H100);
        assert!(h.decode_secs(64, 32) < a.decode_secs(64, 32));
        assert!(h.request_energy(&rec).total_j() > 0.0);
    }

    #[test]
    fn tenant_aggregation_partitions_the_fleet() {
        let led = RequestLedger::new();
        for (id, tenant) in [(1u64, "a"), (2, "b"), (3, "a")] {
            led.enqueued(id, 0, tenant, 16, 0.0);
            led.admitted(id, 16, 1.0);
            led.decoded(id, 2.0, 1.0, 1.0);
            led.completed(id, 2.0);
        }
        let m = EnergyModel::new(ModelFamily::Llama7b, &A100);
        let snap = led.snapshot();
        let fleet = m.fleet_energy(&snap);
        let tenants = m.energy_by_tenant(&snap);
        assert_eq!(tenants.len(), 2);
        assert_eq!(tenants[0].0, "a");
        let sum: f64 =
            tenants.iter().map(|(_, e)| e.total_j()).sum();
        assert!((sum - fleet.total_j()).abs() < 1e-9);
        assert_eq!(fleet.tokens, 3);
    }
}
