//! Tail-latency explainer: decompose slow requests into causes.
//!
//! Aggregate sketches can say *that* p99 TTFT regressed; the ledger
//! can say *why*, per request. For any quantile band (or the K
//! slowest requests) this module splits each completed request's
//! latency into queueing / capacity-wait / preemption / spill / sync
//! contributions — all taken from the causal buckets the ledger
//! accumulated — and names the dominant cause. `mmserve explain`
//! renders the result.

use crate::substrate::table::Table;

use super::energy::EnergyModel;
use super::{LedgerEvent, LedgerSnapshot, RequestRecord};

/// Modeled cost of one cross-shard page spill, in driving-clock
/// units. Spills are counted events, not timed spans (the interleaved
/// copy hides inside the tick), so the explainer weighs them with the
/// same per-token constant the replay charges for prefill work.
pub const SPILL_COST: f64 = 0.05;

/// Why a slow request was slow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlowCause {
    /// Waited in the arrival queue behind other admissions.
    Queueing,
    /// Admission blocked on KV pool capacity (no free pages).
    KvCapacity,
    /// Evicted and waited for re-admission (plus recompute).
    Preemption,
    /// Page allocations spilled off the home shard.
    ShardSpill,
    /// KV moved over the priced fabric (swap round trips, the
    /// disaggregated prefill→decode handoff).
    Transfer,
    /// Batch-interference idle: scheduled, but waiting behind
    /// co-batched work inside ticks.
    Sync,
}

impl SlowCause {
    pub fn as_str(self) -> &'static str {
        match self {
            SlowCause::Queueing => "queueing",
            SlowCause::KvCapacity => "kv-capacity",
            SlowCause::Preemption => "preemption",
            SlowCause::ShardSpill => "shard-spill",
            SlowCause::Transfer => "transfer",
            SlowCause::Sync => "sync",
        }
    }
}

/// One explained request: its latency decomposition and the named
/// dominant cause.
#[derive(Debug, Clone)]
pub struct ExplainRow {
    pub id: u64,
    pub tenant: String,
    pub replica: u32,
    pub latency: f64,
    pub ttft: f64,
    pub queue: f64,
    pub capacity: f64,
    pub preempt: f64,
    pub spill: f64,
    pub transfer: f64,
    pub sync: f64,
    pub dominant: SlowCause,
}

/// Decompose one completed request (None until completion: a request
/// still in flight has no latency to explain).
pub fn explain_request(rec: &RequestRecord) -> Option<ExplainRow> {
    let latency = rec.latency()?;
    let queue = rec.queue_time;
    let capacity = rec.capacity_wait_time;
    let preempt = rec.preempted_time;
    // Fabric-priced spills are sized by the actual bytes gathered
    // over NVLink; unpriced runs keep the flat per-spill weight.
    let spill = if rec.spill_cost > 0.0 {
        rec.spill_cost
    } else {
        rec.spills as f64 * SPILL_COST
    };
    let transfer = rec.transfer_time;
    let sync = rec.interference_idle;
    let causes = [
        (SlowCause::Queueing, queue),
        (SlowCause::KvCapacity, capacity),
        (SlowCause::Preemption, preempt),
        (SlowCause::ShardSpill, spill),
        (SlowCause::Transfer, transfer),
        (SlowCause::Sync, sync),
    ];
    // First-wins on ties, so the ordering above is the tiebreak
    // priority (deterministic across runs).
    let mut dominant = causes[0];
    for c in &causes[1..] {
        if c.1 > dominant.1 {
            dominant = *c;
        }
    }
    Some(ExplainRow {
        id: rec.id,
        tenant: rec.tenant.clone(),
        replica: rec.replica,
        latency,
        ttft: rec.ttft().unwrap_or(latency),
        queue,
        capacity,
        preempt,
        spill,
        transfer,
        sync,
        dominant: dominant.0,
    })
}

/// Parse a quantile spec like `p99` / `p50` / `p99.9` into the
/// percentile value.
pub fn parse_tail(spec: &str) -> Option<f64> {
    let body = spec.strip_prefix('p').or_else(|| {
        spec.strip_prefix('P')
    })?;
    let p: f64 = body.parse().ok()?;
    if (0.0..=100.0).contains(&p) { Some(p) } else { None }
}

fn completed_by_latency(snap: &LedgerSnapshot)
                        -> Vec<&RequestRecord> {
    let mut recs = snap.completed();
    recs.sort_by(|a, b| {
        let la = a.latency().unwrap_or(0.0);
        let lb = b.latency().unwrap_or(0.0);
        lb.partial_cmp(&la)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    recs
}

/// Explain every completed request at or above latency percentile
/// `p` (the quantile band), slowest first. Rank convention matches
/// `Histogram::percentile`.
pub fn tail_rows(snap: &LedgerSnapshot, p: f64) -> Vec<ExplainRow> {
    let recs = completed_by_latency(snap);
    if recs.is_empty() {
        return Vec::new();
    }
    let n = recs.len();
    let rank = ((p.clamp(0.0, 100.0) / 100.0) * (n - 1) as f64)
        .round() as usize;
    // `recs` is slowest-first; percentile rank counts from the
    // fastest, so the band is the first `n - rank` entries.
    let keep = n - rank.min(n - 1);
    recs.into_iter()
        .take(keep.max(1))
        .filter_map(explain_request)
        .collect()
}

/// Explain the `k` slowest completed requests.
pub fn slowest_rows(snap: &LedgerSnapshot, k: usize)
                    -> Vec<ExplainRow> {
    completed_by_latency(snap)
        .into_iter()
        .take(k)
        .filter_map(explain_request)
        .collect()
}

/// Render explainer rows as the `mmserve explain` table.
pub fn render_rows(title: &str, rows: &[ExplainRow]) -> String {
    let mut out = format!("-- {title} ({} requests) --\n", rows.len());
    let mut table = Table::new(&[
        "req", "tenant", "replica", "latency", "ttft", "queue",
        "kv-capacity", "preempt", "spill", "transfer", "sync",
        "dominant",
    ]);
    for r in rows {
        table.row(&[
            r.id.to_string(),
            r.tenant.clone(),
            r.replica.to_string(),
            format!("{:.2}", r.latency),
            format!("{:.2}", r.ttft),
            format!("{:.2}", r.queue),
            format!("{:.2}", r.capacity),
            format!("{:.2}", r.preempt),
            format!("{:.2}", r.spill),
            format!("{:.2}", r.transfer),
            format!("{:.2}", r.sync),
            r.dominant.as_str().to_string(),
        ]);
    }
    out.push_str(&table.render());
    out
}

/// Render one request's causal timeline (consecutive decode ticks
/// coalesced), cost buckets, and — when an energy model is given —
/// its Joule attribution.
pub fn render_request(
    rec: &RequestRecord,
    model: Option<&EnergyModel>,
) -> String {
    let mut out = format!(
        "request {} (tenant {}, replica {}): prompt {} tok, decoded \
         {} tok, ttft {}, latency {}\n",
        rec.id,
        if rec.tenant.is_empty() { "-" } else { &rec.tenant },
        rec.replica,
        rec.prompt_len,
        rec.decoded,
        rec.ttft()
            .map(|t| format!("{t:.2}"))
            .unwrap_or_else(|| "-".to_string()),
        rec.latency()
            .map(|t| format!("{t:.2}"))
            .unwrap_or_else(|| "-".to_string()),
    );

    out.push_str("\n-- causal timeline --\n");
    let mut i = 0usize;
    while i < rec.events.len() {
        let e = &rec.events[i];
        if e.ev == LedgerEvent::DecodeTick {
            // Coalesce the run of decode ticks into one line.
            let mut j = i;
            while j + 1 < rec.events.len()
                && rec.events[j + 1].ev == LedgerEvent::DecodeTick
            {
                j += 1;
            }
            out.push_str(&format!(
                "  t={:8.2} .. {:8.2}  decode ×{}\n",
                e.t,
                rec.events[j].t,
                j - i + 1
            ));
            i = j + 1;
            continue;
        }
        let detail = match e.ev {
            LedgerEvent::Routed { replica } => {
                format!(" -> replica {replica}")
            }
            LedgerEvent::Admitted { tokens }
            | LedgerEvent::PrefillChunk { tokens } => {
                format!(" ({tokens} tok)")
            }
            LedgerEvent::Completed { decoded } => {
                format!(" ({decoded} tok)")
            }
            _ => String::new(),
        };
        out.push_str(&format!(
            "  t={:8.2}              {}{detail}\n",
            e.t,
            e.ev.label()
        ));
        i += 1;
    }

    out.push_str("\n-- cost buckets --\n");
    let mut table = Table::new(&["bucket", "time"]);
    for (label, v) in [
        ("queueing", rec.queue_time),
        ("kv-capacity wait", rec.capacity_wait_time),
        ("preempted", rec.preempted_time),
        ("fabric transfer", rec.transfer_time),
        ("sync (interference)", rec.interference_idle),
        ("prefill compute", rec.prefill_compute),
        ("decode compute", rec.decode_compute),
        ("page-seconds", rec.page_seconds),
    ] {
        table.row(&[label.to_string(), format!("{v:.3}")]);
    }
    out.push_str(&table.render());
    if let Some(row) = explain_request(rec) {
        out.push_str(&format!(
            "dominant slow-cause: {}\n",
            row.dominant.as_str()
        ));
    }

    if let Some(m) = model {
        let e = m.request_energy(rec);
        out.push_str(&format!(
            "\n-- modeled energy ({} on {}) --\n  prefill {:.3} J + \
             decode {:.3} J + idle {:.3} J = {:.3} J  ({} tok, {:.1} \
             tok/J)\n",
            m.family.as_str(),
            m.device.name,
            e.prefill_j,
            e.decode_j,
            e.idle_j,
            e.total_j(),
            e.tokens,
            e.tokens_per_joule()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::energy::ModelFamily;
    use super::super::{RequestLedger, TickCharges};
    use super::*;
    use crate::perfmodel::device::A100;

    /// Build a small fleet where each request has a different
    /// engineered dominant cause.
    fn fleet() -> LedgerSnapshot {
        let led = RequestLedger::new();
        // req 1: long queue.
        led.enqueued(1, 0, "a", 8, 0.0);
        led.charge_tick(&TickCharges {
            dt: 10.0,
            blocked_on_capacity: false,
            waiting: &[1],
            prefill: &[],
            pages: &[],
        });
        led.admitted(1, 8, 10.0);
        led.first_token(1, 10.5);
        led.decoded(1, 10.5, 0.5, 0.5);
        led.completed(1, 11.0);
        // req 2: capacity-blocked admission.
        led.enqueued(2, 0, "b", 8, 0.0);
        led.charge_tick(&TickCharges {
            dt: 6.0,
            blocked_on_capacity: true,
            waiting: &[2],
            prefill: &[],
            pages: &[],
        });
        led.admitted(2, 8, 6.0);
        led.first_token(2, 6.5);
        led.decoded(2, 6.5, 0.5, 0.5);
        led.completed(2, 7.0);
        // req 3: preempted mid-decode.
        led.enqueued(3, 0, "a", 8, 0.0);
        led.admitted(3, 8, 0.5);
        led.first_token(3, 1.0);
        led.decoded(3, 1.0, 0.5, 0.5);
        led.preempted(3, 1.0);
        led.charge_tick(&TickCharges {
            dt: 4.0,
            blocked_on_capacity: false,
            waiting: &[3],
            prefill: &[],
            pages: &[],
        });
        led.admitted(3, 8, 5.0);
        led.decoded(3, 5.5, 0.5, 0.5);
        led.completed(3, 5.5);
        // req 4: fast, interference-bound.
        led.enqueued(4, 0, "b", 8, 0.0);
        led.admitted(4, 8, 0.1);
        led.first_token(4, 0.6);
        led.decoded(4, 0.6, 0.5, 0.1);
        led.completed(4, 1.1);
        led.snapshot()
    }

    #[test]
    fn dominant_causes_are_named_per_request() {
        let snap = fleet();
        let rows = slowest_rows(&snap, 10);
        assert_eq!(rows.len(), 4);
        let by_id = |id: u64| {
            rows.iter().find(|r| r.id == id).unwrap().dominant
        };
        assert_eq!(by_id(1), SlowCause::Queueing);
        assert_eq!(by_id(2), SlowCause::KvCapacity);
        assert_eq!(by_id(3), SlowCause::Preemption);
        assert_eq!(by_id(4), SlowCause::Sync);
        // Slowest first.
        assert_eq!(rows[0].id, 1);
    }

    #[test]
    fn tail_band_keeps_the_slow_end() {
        let snap = fleet();
        let p99 = tail_rows(&snap, 99.0);
        assert!(!p99.is_empty() && p99.len() < 4);
        assert_eq!(p99[0].id, 1, "p99 band holds the slowest request");
        let p0 = tail_rows(&snap, 0.0);
        assert_eq!(p0.len(), 4, "p0 band holds everything");
        // Every row names a dominant cause (acceptance criterion).
        for r in &p0 {
            assert!(!r.dominant.as_str().is_empty());
        }
    }

    #[test]
    fn spill_weight_can_dominate() {
        let led = RequestLedger::new();
        led.enqueued(9, 0, "-", 4, 0.0);
        led.admitted(9, 4, 0.1);
        for _ in 0..40 {
            led.spill(9, 0.0, 0.2);
        }
        led.first_token(9, 0.5);
        led.decoded(9, 0.5, 0.4, 0.4);
        led.completed(9, 0.6);
        let snap = led.snapshot();
        let row = explain_request(snap.get(9).unwrap()).unwrap();
        assert_eq!(row.dominant, SlowCause::ShardSpill);
        assert!((row.spill - 40.0 * SPILL_COST).abs() < 1e-9);
    }

    #[test]
    fn priced_spills_are_sized_by_modeled_bytes() {
        // The same spill count with a fabric-priced cost: the band is
        // the priced NVLink gather time, not count × flat weight.
        let led = RequestLedger::new();
        led.enqueued(9, 0, "-", 4, 0.0);
        led.admitted(9, 4, 0.1);
        for _ in 0..4 {
            led.spill(9, 0.02, 0.2);
        }
        led.first_token(9, 0.5);
        led.decoded(9, 0.5, 0.4, 0.4);
        led.completed(9, 0.6);
        let snap = led.snapshot();
        let row = explain_request(snap.get(9).unwrap()).unwrap();
        assert!((row.spill - 0.08).abs() < 1e-9,
                "priced band, not {} × SPILL_COST: {}",
                4, row.spill);
    }

    #[test]
    fn transfer_band_can_dominate_the_tail() {
        // A disaggregated handoff (or heavy swap traffic) shows up as
        // its own named cause in the decomposition.
        let led = RequestLedger::new();
        led.enqueued(11, 0, "-", 150, 0.0);
        led.admitted(11, 150, 0.1);
        led.transfer(11, 78_643_200, 6.3, 0.2);
        led.first_token(11, 6.6);
        led.decoded(11, 6.6, 0.5, 0.4);
        led.completed(11, 7.1);
        let snap = led.snapshot();
        let rec = snap.get(11).unwrap();
        let row = explain_request(rec).unwrap();
        assert_eq!(row.dominant, SlowCause::Transfer);
        assert!((row.transfer - 6.3).abs() < 1e-9);
        let table = render_rows("tail p0", &tail_rows(&snap, 0.0));
        assert!(table.contains("transfer"));
        let one = render_request(rec, None);
        assert!(one.contains("fabric transfer"));
        assert!(one.contains("dominant slow-cause: transfer"));
    }

    #[test]
    fn parse_tail_accepts_p_specs() {
        assert_eq!(parse_tail("p99"), Some(99.0));
        assert_eq!(parse_tail("P50"), Some(50.0));
        assert_eq!(parse_tail("p99.9"), Some(99.9));
        assert_eq!(parse_tail("99"), None);
        assert_eq!(parse_tail("p101"), None);
    }

    #[test]
    fn renders_table_timeline_and_energy() {
        let snap = fleet();
        let table = render_rows("tail p99", &tail_rows(&snap, 99.0));
        assert!(table.contains("dominant"));
        assert!(table.contains("queueing"));
        let m = EnergyModel::new(ModelFamily::Llama7b, &A100);
        let one = render_request(snap.get(3).unwrap(), Some(&m));
        assert!(one.contains("causal timeline"));
        assert!(one.contains("preempted"));
        assert!(one.contains("resumed"));
        assert!(one.contains("tok/J"));
        assert!(one.contains("dominant slow-cause: preemption"));
        // Decode ticks coalesce: no bare "decode-tick ×1"-per-line
        // spam for a two-token run.
        let incomplete = render_request(snap.get(1).unwrap(), None);
        assert!(incomplete.contains("decode ×1"));
    }
}
