//! Idle-gap attribution: the paper's "GPU idle" decomposition.
//!
//! The characterization result driving every optimization lever is
//! that auto-regressive generation is typically dominated by time the
//! device spends *not* executing (Obs #2). This pass takes a trace,
//! finds the gaps between device dispatches on each worker, and
//! classifies each gap by the host-side work recorded inside it:
//! scheduling (batcher admission), tokenization, sampling,
//! host-device sync (uploads/downloads), stage compilation, or
//! unattributed host time.

use crate::substrate::metrics::OpTimes;
use crate::substrate::table::Table;

use super::tracer::{union_len, Cat, Trace};

/// Gap-classification buckets. `Sync` covers both transfer directions;
/// `KvCapacity` is admission time blocked on the paged KV pool (free
/// slots existed but no pages — the capacity wait the kvpool subsystem
/// turns into batch occupancy); `PrefillStall` is decode-ready slots
/// waiting behind admission prefill work inside a tick — the
/// interference window that chunked prefill (`--chunk-prefill`)
/// bounds.
pub const GAP_CATEGORIES: [&str; 8] = [
    "Scheduling", "KvCapacity", "PrefillStall", "Sampling",
    "Tokenization", "Sync", "Compile", "Other",
];

pub(crate) fn gap_label(cat: Cat) -> Option<&'static str> {
    match cat {
        // Tick planning and replica routing are scheduler work; they
        // share the bucket.
        Cat::Schedule | Cat::Plan | Cat::Route => Some("Scheduling"),
        Cat::KvWait => Some("KvCapacity"),
        Cat::PrefillStall => Some("PrefillStall"),
        Cat::Sample => Some("Sampling"),
        Cat::Tokenize => Some("Tokenization"),
        Cat::Upload | Cat::Download => Some("Sync"),
        Cat::Compile => Some("Compile"),
        // Phase wrappers and Execute itself never attribute gap time.
        _ => None,
    }
}

/// The measured split of a run's wall time into device-execute time
/// and classified idle gaps.
#[derive(Debug, Clone, Default)]
pub struct Attribution {
    /// Union of device-execute intervals (device busy).
    pub execute: f64,
    /// Idle-gap time by `GAP_CATEGORIES` bucket.
    pub gaps: OpTimes,
    /// Analyzed wall time (first dispatch start → last dispatch end,
    /// summed over workers).
    pub wall: f64,
}

impl Attribution {
    /// Classify inter-dispatch gaps for every worker in the trace.
    pub fn from_trace(tr: &Trace) -> Attribution {
        let mut out = Attribution::default();
        for key in GAP_CATEGORIES {
            out.gaps.add(key, 0.0); // all buckets always present
        }
        let mut tids: Vec<u64> = tr.spans.iter().map(|s| s.tid).collect();
        tids.sort();
        tids.dedup();
        for tid in tids {
            out.accumulate_tid(tr, tid);
        }
        out
    }

    fn accumulate_tid(&mut self, tr: &Trace, tid: u64) {
        let spans = tr.spans_on(tid);
        let exec: Vec<(f64, f64)> = spans
            .iter()
            .filter(|s| s.cat == Cat::Execute)
            .map(|s| (s.t0, s.t1))
            .collect();
        if exec.is_empty() {
            return;
        }
        let w0 = exec.iter().map(|e| e.0).fold(f64::INFINITY, f64::min);
        let w1 = exec.iter().map(|e| e.1).fold(f64::NEG_INFINITY, f64::max);
        self.wall += w1 - w0;
        self.execute += union_len(exec.clone());

        // Complement of the execute union inside [w0, w1] = the gaps.
        let mut merged = exec;
        merged.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut gaps: Vec<(f64, f64)> = Vec::new();
        let mut cursor = w0;
        for (a, b) in merged {
            if a > cursor {
                gaps.push((cursor, a));
            }
            cursor = cursor.max(b);
        }

        // Attributable host work on this worker, time-ordered.
        let mut host: Vec<(f64, f64, &'static str)> = spans
            .iter()
            .filter_map(|s| gap_label(s.cat).map(|l| (s.t0, s.t1, l)))
            .collect();
        host.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        // Both `gaps` and `host` are time-ordered, so a host span that
        // ends before the current gap's start can never matter again —
        // `hi` advances monotonically and the sweep is O(gaps + host).
        let mut hi = 0usize;
        for (g0, g1) in gaps {
            while hi < host.len() && host[hi].1 <= g0 {
                hi += 1;
            }
            let mut p = g0;
            for &(h0, h1, label) in &host[hi..] {
                if h0 >= g1 {
                    break;
                }
                if h1 <= p {
                    continue;
                }
                let start = h0.max(p);
                if start > p {
                    self.gaps.add("Other", start - p);
                    p = start;
                }
                let end = h1.min(g1);
                if end > p {
                    self.gaps.add(label, end - p);
                    p = end;
                }
                if p >= g1 {
                    break;
                }
            }
            if p < g1 {
                self.gaps.add("Other", g1 - p);
            }
        }
    }

    /// Total classified idle time.
    pub fn idle_total(&self) -> f64 {
        self.gaps.total()
    }

    /// Device-busy fraction of the analyzed wall time.
    pub fn execute_fraction(&self) -> f64 {
        if self.wall == 0.0 {
            return 0.0;
        }
        self.execute / self.wall
    }

    /// Render as a percentage table — the measured counterpart of the
    /// perfmodel's Idle bucket, split by cause. Percentages are
    /// against the dispatch-window total this pass analyzed.
    pub fn render(&self) -> String {
        self.render_with_wall(self.wall)
    }

    /// Render with an explicit percentage denominator. A partial
    /// trace (spans missing at the edges) has a dispatch window
    /// shorter than the run's real wall time; dividing by the span
    /// total inflates every idle percentage. Callers that know the
    /// true wall (e.g. `TraceReport`) pass it here; the dispatch
    /// window is still printed with its own share so the coverage gap
    /// is visible rather than silently renormalized away.
    pub fn render_with_wall(&self, wall: f64) -> String {
        let mut table = Table::new(&["bucket", "time(ms)", "% of wall"]);
        let pct = |t: f64| {
            if wall > 0.0 { t / wall * 100.0 } else { 0.0 }
        };
        table.row(&[
            "Execute (device busy)".to_string(),
            format!("{:.3}", self.execute * 1e3),
            format!("{:.1}%", pct(self.execute)),
        ]);
        for key in GAP_CATEGORIES {
            let t = self.gaps.get(key);
            table.row(&[
                format!("Idle / {key}"),
                format!("{:.3}", t * 1e3),
                format!("{:.1}%", pct(t)),
            ]);
        }
        table.row(&[
            "wall (dispatch window)".to_string(),
            format!("{:.3}", self.wall * 1e3),
            format!("{:.1}%", pct(self.wall)),
        ]);
        table.render()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tracer::Span;
    use super::*;

    fn sp(cat: Cat, t0: f64, t1: f64) -> Span {
        Span { name: cat.as_str().to_string(), cat, t0, t1, tid: 1,
               req: None, tick: None }
    }

    fn trace(spans: Vec<Span>) -> Trace {
        Trace { spans, workers: vec![(1, "w".into())] }
    }

    #[test]
    fn splits_gap_into_categories() {
        // execute [0,1] … gap [1,2] … execute [2,3]
        // gap = 0.3 schedule + 0.2 tokenize + 0.2 sample + 0.2 sync
        //       + 0.1 unattributed
        let t = trace(vec![
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::Schedule, 1.0, 1.3),
            sp(Cat::Tokenize, 1.3, 1.5),
            sp(Cat::Sample, 1.5, 1.7),
            sp(Cat::Upload, 1.7, 1.9),
            sp(Cat::Execute, 2.0, 3.0),
        ]);
        let a = Attribution::from_trace(&t);
        assert!((a.wall - 3.0).abs() < 1e-9);
        assert!((a.execute - 2.0).abs() < 1e-9);
        assert!((a.gaps.get("Scheduling") - 0.3).abs() < 1e-9);
        assert!((a.gaps.get("Tokenization") - 0.2).abs() < 1e-9);
        assert!((a.gaps.get("Sampling") - 0.2).abs() < 1e-9);
        assert!((a.gaps.get("Sync") - 0.2).abs() < 1e-9);
        assert!((a.gaps.get("Other") - 0.1).abs() < 1e-9);
        // execute + idle == wall
        assert!((a.execute + a.idle_total() - a.wall).abs() < 1e-9);
    }

    #[test]
    fn host_work_overlapping_execute_not_counted() {
        // A sample span inside the execute window must not create idle.
        let t = trace(vec![
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::Sample, 0.2, 0.4),
            sp(Cat::Execute, 1.0, 2.0),
        ]);
        let a = Attribution::from_trace(&t);
        assert!((a.idle_total()).abs() < 1e-9);
        assert!((a.execute_fraction() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn all_buckets_always_present() {
        let a = Attribution::from_trace(&trace(vec![]));
        for key in GAP_CATEGORIES {
            assert!(a.gaps.entries().any(|(k, _)| k == key), "{key}");
        }
        assert_eq!(a.wall, 0.0);
        let s = a.render();
        assert!(s.contains("Scheduling"));
        assert!(s.contains("Sync"));
    }

    #[test]
    fn kv_capacity_wait_gets_its_own_bucket() {
        // execute [0,1] … blocked admission [1,1.6] … execute [2,3]
        let t = trace(vec![
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::KvWait, 1.0, 1.6),
            sp(Cat::Schedule, 1.6, 1.8),
            sp(Cat::Execute, 2.0, 3.0),
        ]);
        let a = Attribution::from_trace(&t);
        assert!((a.gaps.get("KvCapacity") - 0.6).abs() < 1e-9);
        assert!((a.gaps.get("Scheduling") - 0.2).abs() < 1e-9);
        assert!((a.gaps.get("Other") - 0.2).abs() < 1e-9);
        let s = a.render();
        assert!(s.contains("KvCapacity"));
    }

    /// The chunked-prefill story: decode-ready slots stalled behind
    /// admission prefill get their own bucket, and the stall wrapper
    /// subsumes the host work nested inside it.
    #[test]
    fn prefill_stall_gets_its_own_bucket_and_subsumes_nested_work() {
        // decode execute [0,1] … stall window [1,3] wrapping a nested
        // tokenize + the admission prefill dispatch … decode [3,4].
        let t = trace(vec![
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::PrefillStall, 1.0, 3.0),
            sp(Cat::Tokenize, 1.0, 1.2),
            sp(Cat::Execute, 1.5, 2.5),
            sp(Cat::Execute, 3.0, 4.0),
        ]);
        let a = Attribution::from_trace(&t);
        // Idle gaps [1,1.5] and [2.5,3] both fall inside the stall.
        assert!((a.gaps.get("PrefillStall") - 1.0).abs() < 1e-9);
        assert!((a.gaps.get("Tokenization")).abs() < 1e-9,
                "stall wrapper owns the nested host time");
        assert!(a.render().contains("PrefillStall"));
    }

    /// `Scheduler::plan` spans share the Scheduling bucket.
    #[test]
    fn plan_spans_attribute_to_scheduling() {
        let t = trace(vec![
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::Plan, 1.0, 1.4),
            sp(Cat::Execute, 2.0, 3.0),
        ]);
        let a = Attribution::from_trace(&t);
        assert!((a.gaps.get("Scheduling") - 0.4).abs() < 1e-9);
        assert!((a.gaps.get("Other") - 0.6).abs() < 1e-9);
    }

    #[test]
    fn phase_spans_do_not_attribute() {
        let t = trace(vec![
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::Decode, 0.0, 3.0), // wrapper over the whole tick
            sp(Cat::Execute, 2.0, 3.0),
        ]);
        let a = Attribution::from_trace(&t);
        assert!((a.gaps.get("Other") - 1.0).abs() < 1e-9);
        assert!((a.gaps.get("Scheduling")).abs() < 1e-9);
    }
}
