//! Request-path tracing & idle-time attribution.
//!
//! The paper's core characterization result (Obs #2) is that
//! auto-regressive generation is typically dominated by GPU *idle*
//! time, and its Figure-3/4 methodology rests on per-operator
//! wall-time timelines. This subsystem records exactly that timeline
//! from the live serving path and decomposes the gaps between device
//! dispatches into their host-side causes:
//!
//! * [`tracer`] — low-overhead span recorder: begin/end spans with
//!   worker id, category, request id and scheduler tick, buffered
//!   per worker; a single relaxed atomic load when disabled.
//! * [`timeline`] — per-scheduler-tick step records folded from
//!   tick-tagged spans (prefill/decode/sample/host-gap phases).
//! * [`attribution`] — classifies inter-dispatch gaps into
//!   scheduling / tokenization / sampling / host-device sync /
//!   compile / other — the measured "GPU idle" decomposition.
//! * [`chrome_trace`] — `about://tracing`-compatible JSON export.
//! * [`aggregate`] — folds spans into `substrate::metrics` (TTFT and
//!   time-between-tokens histograms, per-category/per-stage totals).
//! * [`report`] — the text report printed by `mmserve trace` next to
//!   the analytical perfmodel projection.
//! * [`live`] — the mid-run plane: labeled atomic registry with
//!   streaming quantile sketches, per-tick fleet sampler, online
//!   idle-gap attribution, flight recorder, and Prometheus text
//!   exposition (`mmserve stats`, `--metrics-out`).
//! * [`ledger`] — the per-request causal cost ledger: typed event
//!   chains across router → admission → ticks → kvpool, per-phase
//!   compute/idle buckets, page-seconds, modeled Joules
//!   ([`ledger::energy`]), and the tail-latency explainer
//!   ([`ledger::explain`], `mmserve explain`).
//!
//! Wiring: `Engine` holds an optional [`tracer::WorkerTracer`] and
//! wraps every PJRT execute / upload / download / compile in a span;
//! the coordinator workers tag spans with the current request and
//! scheduler tick. Pass a [`tracer::Tracer`] in `RouterConfig` (or
//! call `Engine::set_tracer`) to turn it on; when absent or disabled
//! the serving path is unaffected.

pub mod aggregate;
pub mod attribution;
pub mod chrome_trace;
pub mod ledger;
pub mod live;
pub mod report;
pub mod timeline;
pub mod tracer;

pub use aggregate::Aggregate;
pub use attribution::Attribution;
pub use ledger::{LedgerSnapshot, RequestLedger, RequestRecord};
pub use live::{FlightRecorder, LiveMetrics, MetricsSnapshot,
               OnlineAttribution, QuantileSketch, WorkerSampler};
pub use report::TraceReport;
pub use timeline::Timeline;
pub use tracer::{Cat, ReqScope, Span, SpanGuard, TickScope, Trace,
                 Tracer, WorkerTracer};
