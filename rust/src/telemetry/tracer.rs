//! Low-overhead span recorder for the live serving path.
//!
//! A `Tracer` is a cloneable process-level handle; each worker thread
//! registers a `WorkerTracer` whose spans land in its own
//! mutex-protected buffer (uncontended except at drain time, so the
//! hot path is effectively lock-free). Spans carry a category, an
//! optional request id and scheduler-tick index, and wall-clock bounds
//! measured against the tracer's monotonic epoch.
//!
//! Disabled mode is a single relaxed atomic load per would-be span —
//! no clock read, no allocation, no lock — so the serving path is
//! unaffected when tracing is off.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Sentinel for "no request / no tick" in the per-worker context cells.
const NONE: u64 = u64::MAX;

/// Span categories — the vocabulary of the paper's Fig-3/4 timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Cat {
    /// A PJRT executable dispatch (device busy time).
    Execute,
    /// Stage compilation (startup / first-use).
    Compile,
    /// Host→device transfer (sync).
    Upload,
    /// Device→host transfer (sync).
    Download,
    /// Batcher admission / slot bookkeeping.
    Schedule,
    /// Replica selection on the router thread: ranking a model's
    /// replicas by cached-prefix warmth / queue depth before the
    /// request is handed to a worker channel.
    Route,
    /// Scheduler tick planning (`Scheduler::plan` → `TickPlan`).
    Plan,
    /// Decode-ready slots stalled behind admission prefill work inside
    /// a tick — the prefill/decode-interference window that chunked
    /// prefill bounds. Recorded as a wrapper over the tick's chunk
    /// execution when decode jobs are live.
    PrefillStall,
    /// Admission blocked on KV-cache capacity (free slots exist but the
    /// page budget cannot cover the next prompt) — the paged-pool
    /// analogue of queueing delay, split out so the idle attribution
    /// can separate "scheduler busy" from "waiting for pages".
    KvWait,
    /// Text/image/speech (de)tokenization and featurization.
    Tokenize,
    /// Host-side sampling / beam bookkeeping.
    Sample,
    /// Logical prefill phase (wraps nested Execute/Upload spans).
    Prefill,
    /// Logical decode-step phase (wraps one scheduler tick's work).
    Decode,
    /// Anything else (phase markers, setup).
    Other,
}

impl Cat {
    pub fn as_str(self) -> &'static str {
        match self {
            Cat::Execute => "Execute",
            Cat::Compile => "Compile",
            Cat::Upload => "Upload",
            Cat::Download => "Download",
            Cat::Schedule => "Schedule",
            Cat::Route => "Route",
            Cat::Plan => "Plan",
            Cat::PrefillStall => "PrefillStall",
            Cat::KvWait => "KvWait",
            Cat::Tokenize => "Tokenize",
            Cat::Sample => "Sample",
            Cat::Prefill => "Prefill",
            Cat::Decode => "Decode",
            Cat::Other => "Other",
        }
    }
}

/// A completed span. Times are seconds since the tracer epoch.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: String,
    pub cat: Cat,
    pub t0: f64,
    pub t1: f64,
    /// Worker (thread) id assigned at registration.
    pub tid: u64,
    pub req: Option<u64>,
    /// Scheduler tick the span belongs to, if any.
    pub tick: Option<u64>,
}

impl Span {
    pub fn dur(&self) -> f64 {
        self.t1 - self.t0
    }
}

#[derive(Debug)]
struct TracerCore {
    enabled: AtomicBool,
    epoch: Instant,
    next_tid: AtomicU64,
    /// (tid, worker name, span buffer) per registered worker.
    sinks: Mutex<Vec<(u64, String, Arc<Mutex<Vec<Span>>>)>>,
}

/// Process-level tracing handle (cheap to clone; `Send + Sync`).
#[derive(Debug, Clone)]
pub struct Tracer {
    core: Arc<TracerCore>,
}

impl Tracer {
    /// An enabled tracer.
    pub fn new() -> Self {
        Self::with_enabled(true)
    }

    /// A disabled tracer: spans are no-ops until `set_enabled(true)`.
    pub fn off() -> Self {
        Self::with_enabled(false)
    }

    fn with_enabled(on: bool) -> Self {
        Tracer {
            core: Arc::new(TracerCore {
                enabled: AtomicBool::new(on),
                epoch: Instant::now(),
                next_tid: AtomicU64::new(1),
                sinks: Mutex::new(Vec::new()),
            }),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.core.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.core.enabled.store(on, Ordering::Relaxed);
    }

    /// Register a worker thread; spans from the returned handle are
    /// tagged with a fresh tid and buffered separately.
    pub fn worker(&self, name: &str) -> WorkerTracer {
        let tid = self.core.next_tid.fetch_add(1, Ordering::Relaxed);
        let sink = Arc::new(Mutex::new(Vec::new()));
        self.core
            .sinks
            .lock()
            .unwrap()
            .push((tid, name.to_string(), sink.clone()));
        WorkerTracer {
            core: self.core.clone(),
            sink,
            tid,
            cur_req: Arc::new(AtomicU64::new(NONE)),
            cur_tick: Arc::new(AtomicU64::new(NONE)),
            tick_counter: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Collect (and clear) all recorded spans, sorted by start time.
    pub fn drain(&self) -> Trace {
        let mut spans = Vec::new();
        let mut workers = Vec::new();
        for (tid, name, sink) in self.core.sinks.lock().unwrap().iter() {
            workers.push((*tid, name.clone()));
            spans.append(&mut sink.lock().unwrap());
        }
        spans.sort_by(|a, b| a.t0.partial_cmp(&b.t0).unwrap());
        Trace { spans, workers }
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

/// Per-worker recording handle. Clones share the same buffer and
/// request/tick context cells (the engine holds a clone so its dispatch
/// spans inherit the worker's current request/tick).
#[derive(Debug, Clone)]
pub struct WorkerTracer {
    core: Arc<TracerCore>,
    sink: Arc<Mutex<Vec<Span>>>,
    tid: u64,
    cur_req: Arc<AtomicU64>,
    cur_tick: Arc<AtomicU64>,
    /// Monotonic per-worker tick source (never reused, so ticks from
    /// different requests on one worker can't collide).
    tick_counter: Arc<AtomicU64>,
}

impl WorkerTracer {
    pub fn tid(&self) -> u64 {
        self.tid
    }

    pub fn is_enabled(&self) -> bool {
        self.core.enabled.load(Ordering::Relaxed)
    }

    /// Set the ambient request id inherited by subsequent spans.
    pub fn set_req(&self, id: u64) {
        self.cur_req.store(id, Ordering::Relaxed);
    }
    pub fn clear_req(&self) {
        self.cur_req.store(NONE, Ordering::Relaxed);
    }

    /// RAII scope that makes `id` the ambient request and clears it on
    /// drop — survives early `?` returns, so a failed request can't
    /// leak its id onto the next request's spans.
    pub fn req_scope(&self, id: u64) -> ReqScope<'_> {
        self.set_req(id);
        ReqScope { wt: self }
    }

    /// Set the ambient scheduler-tick index.
    pub fn set_tick(&self, tick: u64) {
        self.cur_tick.store(tick, Ordering::Relaxed);
    }
    pub fn clear_tick(&self) {
        self.cur_tick.store(NONE, Ordering::Relaxed);
    }

    /// Advance to a fresh, worker-unique tick and make it ambient.
    /// The counter is shared by all clones (worker + engine), so ticks
    /// stay monotonic across requests on the same worker.
    pub fn next_tick(&self) -> u64 {
        let t = self.tick_counter.fetch_add(1, Ordering::Relaxed);
        self.cur_tick.store(t, Ordering::Relaxed);
        t
    }

    /// RAII scope that clears the ambient tick on entry and on drop —
    /// use around a per-request generation so neither a stale tick
    /// from an enclosing loop nor an early `?` exit can mis-tag spans.
    pub fn tick_scope(&self) -> TickScope<'_> {
        self.clear_tick();
        TickScope { wt: self }
    }

    /// Copy spans recorded since `cursor` (a count previously returned
    /// by this method) without draining them, and return the new
    /// cursor. The live sampler calls this once per scheduler tick to
    /// fold idle-gap attribution online while the full buffer stays
    /// intact for post-hoc reports; a `Tracer::drain` in between
    /// resets the buffer, and the cursor clamp makes that safe.
    pub fn spans_since(&self, cursor: usize) -> (usize, Vec<Span>) {
        let sink = self.sink.lock().unwrap();
        let start = cursor.min(sink.len());
        (sink.len(), sink[start..].to_vec())
    }

    /// Begin a span; it records itself on drop. Near-zero cost when
    /// tracing is disabled (one relaxed load, no clock read).
    pub fn span(&self, cat: Cat, name: &str) -> SpanGuard<'_> {
        self.begin(cat, name, None)
    }

    /// Begin a span explicitly bound to a request id.
    pub fn span_req(&self, cat: Cat, name: &str, req: u64) -> SpanGuard<'_> {
        self.begin(cat, name, Some(req))
    }

    fn begin(&self, cat: Cat, name: &str, req: Option<u64>) -> SpanGuard<'_> {
        if !self.is_enabled() {
            return SpanGuard { wt: self, meta: None };
        }
        SpanGuard {
            wt: self,
            meta: Some(SpanMeta {
                name: name.to_string(),
                cat,
                req,
                start: Instant::now(),
            }),
        }
    }
}

/// Run `f` under a span (when `wt` is tracing) and return its result
/// plus the measured wall-clock seconds. The measurement itself does
/// not depend on tracing being on — callers that keep their own
/// per-stage accumulators (`OpTimes`) get identical numbers either
/// way, with the span recorded as a bonus when a tracer is attached.
pub fn timed<R>(wt: Option<&WorkerTracer>, cat: Cat, name: &str,
                f: impl FnOnce() -> R) -> (R, f64) {
    let guard = wt.map(|t| t.span(cat, name));
    let t0 = Instant::now();
    let r = f();
    let secs = t0.elapsed().as_secs_f64();
    drop(guard);
    (r, secs)
}

/// Clears the worker's ambient tick when dropped (see
/// [`WorkerTracer::tick_scope`]).
pub struct TickScope<'a> {
    wt: &'a WorkerTracer,
}

impl Drop for TickScope<'_> {
    fn drop(&mut self) {
        self.wt.clear_tick();
    }
}

/// Clears the worker's ambient request id when dropped (see
/// [`WorkerTracer::req_scope`]).
pub struct ReqScope<'a> {
    wt: &'a WorkerTracer,
}

impl Drop for ReqScope<'_> {
    fn drop(&mut self) {
        self.wt.clear_req();
    }
}

struct SpanMeta {
    name: String,
    cat: Cat,
    req: Option<u64>,
    start: Instant,
}

/// RAII span: records into the worker buffer on drop.
pub struct SpanGuard<'a> {
    wt: &'a WorkerTracer,
    meta: Option<SpanMeta>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let Some(m) = self.meta.take() else { return };
        let now = Instant::now();
        let epoch = self.wt.core.epoch;
        let cell = |c: &AtomicU64| {
            let v = c.load(Ordering::Relaxed);
            if v == NONE { None } else { Some(v) }
        };
        let span = Span {
            name: m.name,
            cat: m.cat,
            t0: m.start.duration_since(epoch).as_secs_f64(),
            t1: now.duration_since(epoch).as_secs_f64(),
            tid: self.wt.tid,
            req: m.req.or_else(|| cell(&self.wt.cur_req)),
            tick: cell(&self.wt.cur_tick),
        };
        self.wt.sink.lock().unwrap().push(span);
    }
}

/// A drained collection of spans (sorted by start time).
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
    /// (tid, worker name) registry.
    pub workers: Vec<(u64, String)>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.spans.len()
    }
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Wall span of the whole trace (first start to last end).
    pub fn wall(&self) -> f64 {
        let t0 = self.spans.iter().map(|s| s.t0).fold(f64::INFINITY, f64::min);
        let t1 = self
            .spans
            .iter()
            .map(|s| s.t1)
            .fold(f64::NEG_INFINITY, f64::max);
        if t1 > t0 { t1 - t0 } else { 0.0 }
    }

    /// Total recorded time in one category (may double-count nested
    /// spans of the same category; categories here don't nest).
    pub fn total(&self, cat: Cat) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.cat == cat)
            .map(|s| s.dur())
            .sum()
    }

    pub fn spans_on(&self, tid: u64) -> Vec<&Span> {
        self.spans.iter().filter(|s| s.tid == tid).collect()
    }

    /// Fraction of the trace wall time covered by the union of all
    /// span intervals (across workers, projected on one time axis) —
    /// the acceptance metric for "spans cover ≥ X% of the generation".
    pub fn coverage(&self) -> f64 {
        let wall = self.wall();
        if wall == 0.0 {
            return 0.0;
        }
        let ivs: Vec<(f64, f64)> =
            self.spans.iter().map(|s| (s.t0, s.t1)).collect();
        union_len(ivs) / wall
    }

    /// Distinct request ids appearing in the trace.
    pub fn request_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> =
            self.spans.iter().filter_map(|s| s.req).collect();
        ids.sort();
        ids.dedup();
        ids
    }
}

/// Total length of the union of a set of (start, end) intervals.
pub(crate) fn union_len(mut ivs: Vec<(f64, f64)>) -> f64 {
    ivs.retain(|(a, b)| b > a);
    ivs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for (a, b) in ivs {
        match cur {
            Some((c0, c1)) if a <= c1 => {
                cur = Some((c0, c1.max(b)));
            }
            Some((c0, c1)) => {
                total += c1 - c0;
                cur = Some((a, b));
            }
            None => cur = Some((a, b)),
        }
    }
    if let Some((c0, c1)) = cur {
        total += c1 - c0;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_spans_with_context() {
        let tr = Tracer::new();
        let wt = tr.worker("w0");
        wt.set_req(7);
        wt.set_tick(3);
        {
            let _g = wt.span(Cat::Execute, "decode_b4");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        wt.clear_req();
        {
            let _g = wt.span_req(Cat::Sample, "sample", 9);
        }
        let t = tr.drain();
        assert_eq!(t.len(), 2);
        let exec = &t.spans[0];
        assert_eq!(exec.cat, Cat::Execute);
        assert_eq!(exec.req, Some(7));
        assert_eq!(exec.tick, Some(3));
        assert!(exec.dur() >= 0.001);
        assert_eq!(t.spans[1].req, Some(9));
    }

    #[test]
    fn disabled_records_nothing() {
        let tr = Tracer::off();
        let wt = tr.worker("w0");
        for _ in 0..100 {
            let _g = wt.span(Cat::Execute, "x");
        }
        assert_eq!(tr.drain().len(), 0, "disabled mode must record 0 spans");
    }

    #[test]
    fn drain_clears_and_sorts() {
        let tr = Tracer::new();
        let wt = tr.worker("w0");
        {
            let _a = wt.span(Cat::Schedule, "outer");
            let _b = wt.span(Cat::Sample, "inner");
        } // inner drops first but starts later
        let t = tr.drain();
        assert_eq!(t.len(), 2);
        assert!(t.spans[0].t0 <= t.spans[1].t0);
        assert_eq!(t.spans[0].name, "outer");
        assert_eq!(tr.drain().len(), 0);
    }

    #[test]
    fn union_len_merges_overlaps() {
        assert_eq!(union_len(vec![]), 0.0);
        let u = union_len(vec![(0.0, 1.0), (0.5, 2.0), (3.0, 4.0)]);
        assert!((u - 3.0).abs() < 1e-12);
    }

    #[test]
    fn coverage_full_and_partial() {
        let mut t = Trace::default();
        let sp = |t0: f64, t1: f64| Span {
            name: "s".into(),
            cat: Cat::Execute,
            t0,
            t1,
            tid: 1,
            req: None,
            tick: None,
        };
        t.spans = vec![sp(0.0, 1.0), sp(1.0, 2.0)];
        assert!((t.coverage() - 1.0).abs() < 1e-12);
        t.spans = vec![sp(0.0, 1.0), sp(3.0, 4.0)];
        assert!((t.coverage() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn req_scope_clears_on_early_exit() {
        let tr = Tracer::new();
        let wt = tr.worker("w0");
        let failing = || -> Result<(), ()> {
            let _scope = wt.req_scope(42);
            let _g = wt.span(Cat::Tokenize, "tokenize");
            Err(()) // early exit must still clear the ambient req
        };
        assert!(failing().is_err());
        {
            let _g = wt.span(Cat::Schedule, "later");
        }
        let t = tr.drain();
        let tok = t.spans.iter().find(|s| s.name == "tokenize").unwrap();
        assert_eq!(tok.req, Some(42));
        let later = t.spans.iter().find(|s| s.name == "later").unwrap();
        assert_eq!(later.req, None, "req must not leak past the scope");
    }

    #[test]
    fn next_tick_is_monotonic_and_scope_clears() {
        let tr = Tracer::new();
        let wt = tr.worker("w0");
        {
            let _scope = wt.tick_scope();
            assert_eq!(wt.next_tick(), 0);
            assert_eq!(wt.next_tick(), 1);
            let _g = wt.span(Cat::Execute, "x");
        } // scope drops → ambient tick cleared
        {
            let _scope = wt.tick_scope();
            assert_eq!(wt.next_tick(), 2, "counter never rewinds");
        }
        let _g = wt.span(Cat::Other, "after");
        drop(_g);
        let t = tr.drain();
        let exec = t.spans.iter().find(|s| s.name == "x").unwrap();
        assert_eq!(exec.tick, Some(1));
        let after = t.spans.iter().find(|s| s.name == "after").unwrap();
        assert_eq!(after.tick, None, "tick must not leak past the scope");
    }

    #[test]
    fn timed_measures_with_and_without_tracer() {
        let ((), secs) = timed(None, Cat::Execute, "untracked", || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(secs >= 0.001, "timing works with no tracer attached");

        let tr = Tracer::new();
        let wt = tr.worker("w0");
        let (v, secs) =
            timed(Some(&wt), Cat::Tokenize, "tracked", || 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
        let t = tr.drain();
        assert_eq!(t.len(), 1);
        assert_eq!(t.spans[0].name, "tracked");
        assert_eq!(t.spans[0].cat, Cat::Tokenize);
    }

    #[test]
    fn workers_get_distinct_tids() {
        let tr = Tracer::new();
        let a = tr.worker("a");
        let b = tr.worker("b");
        assert_ne!(a.tid(), b.tid());
        {
            let _x = a.span(Cat::Other, "x");
            let _y = b.span(Cat::Other, "y");
        }
        let t = tr.drain();
        assert_eq!(t.workers.len(), 2);
        assert_eq!(t.spans_on(a.tid()).len(), 1);
    }
}
