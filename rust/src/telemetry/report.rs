//! Text-report exporter: the measured Fig-3/4-style breakdown of a
//! trace, suitable for printing next to the analytical perfmodel
//! projection (`mmserve trace` does exactly that).

use super::aggregate::Aggregate;
use super::attribution::Attribution;
use super::timeline::Timeline;
use super::tracer::Trace;

/// Everything the text report derives from one trace.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub aggregate: Aggregate,
    pub attribution: Attribution,
    pub timeline: Timeline,
    pub coverage: f64,
    pub wall: f64,
}

impl TraceReport {
    pub fn from_trace(tr: &Trace) -> TraceReport {
        TraceReport {
            aggregate: Aggregate::from_trace(tr),
            attribution: Attribution::from_trace(tr),
            timeline: Timeline::from_trace(tr),
            coverage: tr.coverage(),
            wall: tr.wall(),
        }
    }

    /// Render the full measured report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace: {} spans, wall {:.2} ms, span coverage {:.1}%\n",
            self.aggregate.span_count,
            self.wall * 1e3,
            self.coverage * 100.0
        ));
        out.push_str(&self.aggregate.latency_summary());
        out.push('\n');
        out.push_str("\n-- measured category breakdown --\n");
        out.push_str(&self.aggregate.render_categories());
        out.push_str("\n-- per-stage dispatch time --\n");
        out.push_str(&self.aggregate.render_stages());
        out.push_str("\n-- idle-gap attribution (the paper's GPU-idle \
                      decomposition) --\n");
        // Percentages against the run's real wall time, not the
        // attribution pass's dispatch-window total: on a partial
        // trace the window is shorter than the wall, and dividing by
        // it inflated every idle bucket.
        out.push_str(&self.attribution.render_with_wall(self.wall));
        if !self.timeline.is_empty() {
            out.push_str(&format!(
                "\n-- step timeline ({} ticks, mean {:.3} ms, execute \
                 fraction {:.1}%) --\n",
                self.timeline.len(),
                self.timeline.mean_tick_secs() * 1e3,
                self.timeline.execute_fraction() * 100.0
            ));
            out.push_str(&self.timeline.render(12));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::tracer::{Cat, Span, Trace};
    use super::*;

    #[test]
    fn report_renders_all_sections() {
        let sp = |cat: Cat, t0: f64, t1: f64, tick: Option<u64>| Span {
            name: cat.as_str().to_string(),
            cat,
            t0,
            t1,
            tid: 1,
            req: Some(1),
            tick,
        };
        let tr = Trace {
            spans: vec![
                sp(Cat::Execute, 0.0, 0.4, Some(0)),
                sp(Cat::Sample, 0.4, 0.5, Some(0)),
                sp(Cat::Execute, 0.5, 0.9, Some(1)),
                sp(Cat::Sample, 0.9, 1.0, Some(1)),
            ],
            workers: vec![(1, "w".into())],
        };
        let rep = TraceReport::from_trace(&tr);
        let s = rep.render();
        assert!(s.contains("span coverage 100.0%"));
        assert!(s.contains("measured category breakdown"));
        assert!(s.contains("idle-gap attribution"));
        assert!(s.contains("step timeline"));
        assert!(rep.coverage > 0.99);
        assert_eq!(rep.timeline.len(), 2);
    }

    /// Partial trace: a host span extends the wall past the dispatch
    /// window, so idle percentages must use the report's wall — not
    /// the attribution span total — as the denominator.
    #[test]
    fn partial_trace_percentages_use_report_wall() {
        let sp = |cat: Cat, t0: f64, t1: f64| Span {
            name: cat.as_str().to_string(),
            cat,
            t0,
            t1,
            tid: 1,
            req: Some(1),
            tick: Some(0),
        };
        // Dispatch window [2,4] (wall 2s, 1s execute + 1s idle), but
        // the trace really spans [0,10]: wall = 10s.
        let tr = Trace {
            spans: vec![
                sp(Cat::Tokenize, 0.0, 10.0),
                sp(Cat::Execute, 2.0, 3.0),
                sp(Cat::Execute, 3.5, 4.0),
            ],
            workers: vec![(1, "w".into())],
        };
        let rep = TraceReport::from_trace(&tr);
        assert!((rep.wall - 10.0).abs() < 1e-9);
        assert!((rep.attribution.wall - 2.0).abs() < 1e-9);
        let s = rep.render();
        // Execute is 1.5s: 15% of the 10s wall — the old span-total
        // denominator would have printed 75.0%.
        assert!(s.contains("15.0%"), "{s}");
        assert!(!s.contains("75.0%"), "{s}");
        // The dispatch window shows its own share of the wall rather
        // than a renormalized 100%.
        assert!(s.contains("20.0%"), "{s}");
    }
}
