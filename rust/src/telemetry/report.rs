//! Text-report exporter: the measured Fig-3/4-style breakdown of a
//! trace, suitable for printing next to the analytical perfmodel
//! projection (`mmserve trace` does exactly that).

use super::aggregate::Aggregate;
use super::attribution::Attribution;
use super::timeline::Timeline;
use super::tracer::Trace;

/// Everything the text report derives from one trace.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub aggregate: Aggregate,
    pub attribution: Attribution,
    pub timeline: Timeline,
    pub coverage: f64,
    pub wall: f64,
}

impl TraceReport {
    pub fn from_trace(tr: &Trace) -> TraceReport {
        TraceReport {
            aggregate: Aggregate::from_trace(tr),
            attribution: Attribution::from_trace(tr),
            timeline: Timeline::from_trace(tr),
            coverage: tr.coverage(),
            wall: tr.wall(),
        }
    }

    /// Render the full measured report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace: {} spans, wall {:.2} ms, span coverage {:.1}%\n",
            self.aggregate.span_count,
            self.wall * 1e3,
            self.coverage * 100.0
        ));
        out.push_str(&self.aggregate.latency_summary());
        out.push('\n');
        out.push_str("\n-- measured category breakdown --\n");
        out.push_str(&self.aggregate.render_categories());
        out.push_str("\n-- per-stage dispatch time --\n");
        out.push_str(&self.aggregate.render_stages());
        out.push_str("\n-- idle-gap attribution (the paper's GPU-idle \
                      decomposition) --\n");
        out.push_str(&self.attribution.render());
        if !self.timeline.is_empty() {
            out.push_str(&format!(
                "\n-- step timeline ({} ticks, mean {:.3} ms, execute \
                 fraction {:.1}%) --\n",
                self.timeline.len(),
                self.timeline.mean_tick_secs() * 1e3,
                self.timeline.execute_fraction() * 100.0
            ));
            out.push_str(&self.timeline.render(12));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::tracer::{Cat, Span, Trace};
    use super::*;

    #[test]
    fn report_renders_all_sections() {
        let sp = |cat: Cat, t0: f64, t1: f64, tick: Option<u64>| Span {
            name: cat.as_str().to_string(),
            cat,
            t0,
            t1,
            tid: 1,
            req: Some(1),
            tick,
        };
        let tr = Trace {
            spans: vec![
                sp(Cat::Execute, 0.0, 0.4, Some(0)),
                sp(Cat::Sample, 0.4, 0.5, Some(0)),
                sp(Cat::Execute, 0.5, 0.9, Some(1)),
                sp(Cat::Sample, 0.9, 1.0, Some(1)),
            ],
            workers: vec![(1, "w".into())],
        };
        let rep = TraceReport::from_trace(&tr);
        let s = rep.render();
        assert!(s.contains("span coverage 100.0%"));
        assert!(s.contains("measured category breakdown"));
        assert!(s.contains("idle-gap attribution"));
        assert!(s.contains("step timeline"));
        assert!(rep.coverage > 0.99);
        assert_eq!(rep.timeline.len(), 2);
    }
}
