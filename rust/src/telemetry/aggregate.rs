//! Aggregation layer: fold a trace into the existing
//! `substrate::metrics` structures — per-category and per-stage
//! wall-time totals (`OpTimes`) and the serving-latency histograms
//! (TTFT, time-between-tokens) the paper's Figure-3 distributions use.

use std::collections::HashMap;

use crate::substrate::metrics::{Histogram, OpTimes};
use crate::substrate::table::Table;

use super::tracer::{Cat, Trace};

/// Metrics folded from one trace.
#[derive(Debug, Clone, Default)]
pub struct Aggregate {
    /// Wall time per span category (keys are `Cat::as_str()`).
    pub per_category: OpTimes,
    /// Wall time per `Execute` span name — the per-stage breakdown
    /// that used to live in the engine's ad-hoc `stage_times`.
    pub per_stage: OpTimes,
    /// Time from a request's first span to its first sampled token (ms).
    pub ttft_ms: Histogram,
    /// Time between consecutive sampled tokens per request (ms).
    pub tbt_ms: Histogram,
    pub span_count: usize,
    /// Requests that emitted spans but never a `Sample` span (killed
    /// mid-prefill, preempted and never resumed, crashed replica) —
    /// they contribute no latency samples but must not vanish from
    /// the report.
    pub incomplete_requests: usize,
}

impl Aggregate {
    pub fn from_trace(tr: &Trace) -> Aggregate {
        let mut agg = Aggregate { span_count: tr.len(), ..Default::default() };
        // Single pass: category/stage totals + per-request latency raw
        // material (first span start, sample-span ends).
        let mut per_req: HashMap<u64, (f64, Vec<f64>)> = HashMap::new();
        for s in &tr.spans {
            // Phase wrappers would double-count the nested work.
            if !matches!(s.cat, Cat::Prefill | Cat::Decode
                                | Cat::PrefillStall | Cat::Other) {
                agg.per_category.add(s.cat.as_str(), s.dur());
            }
            if s.cat == Cat::Execute {
                agg.per_stage.add(&s.name, s.dur());
            }
            if let Some(req) = s.req {
                let e = per_req
                    .entry(req)
                    .or_insert((f64::INFINITY, Vec::new()));
                e.0 = e.0.min(s.t0);
                if s.cat == Cat::Sample {
                    e.1.push(s.t1);
                }
            }
        }
        // Deterministic histogram fill order.
        let mut reqs: Vec<u64> = per_req.keys().copied().collect();
        reqs.sort_unstable();
        for req in reqs {
            let Some((first, mut samples)) = per_req.remove(&req)
            else {
                continue;
            };
            samples.sort_by(|a, b| a.total_cmp(b));
            match samples.first() {
                Some(&t) => agg.ttft_ms.record((t - first) * 1e3),
                None => agg.incomplete_requests += 1,
            }
            for w in samples.windows(2) {
                agg.tbt_ms.record((w[1] - w[0]) * 1e3);
            }
        }
        agg
    }

    /// Per-category table, largest first.
    pub fn render_categories(&self) -> String {
        render_optimes(&self.per_category, "category")
    }

    /// Per-stage table (Execute spans), largest first.
    pub fn render_stages(&self) -> String {
        render_optimes(&self.per_stage, "stage")
    }

    pub fn latency_summary(&self) -> String {
        let mut out = format!(
            "ttft(ms) [{}]\ntbt(ms)  [{}]",
            self.ttft_ms.summary(),
            self.tbt_ms.summary()
        );
        if self.incomplete_requests > 0 {
            out.push_str(&format!(
                "\nincomplete requests (no sampled token): {}",
                self.incomplete_requests
            ));
        }
        out
    }
}

/// Shared renderer: one named-accumulator table, largest first.
fn render_optimes(times: &OpTimes, key_col: &str) -> String {
    let total = times.total();
    let mut rows: Vec<(String, f64)> =
        times.entries().map(|(k, v)| (k.to_string(), v)).collect();
    rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut table = Table::new(&[key_col, "time(ms)", "share"]);
    for (k, v) in rows {
        let share = if total > 0.0 { v / total * 100.0 } else { 0.0 };
        table.row(&[k, format!("{:.3}", v * 1e3), format!("{share:.1}%")]);
    }
    table.render()
}

#[cfg(test)]
mod tests {
    use super::super::tracer::Span;
    use super::*;

    fn sp(cat: Cat, name: &str, t0: f64, t1: f64, req: Option<u64>) -> Span {
        Span { name: name.into(), cat, t0, t1, tid: 1, req, tick: None }
    }

    #[test]
    fn folds_categories_stages_and_latencies() {
        let tr = Trace {
            spans: vec![
                sp(Cat::Tokenize, "tokenize", 0.0, 0.1, Some(1)),
                sp(Cat::Execute, "prefill_b32", 0.1, 0.5, Some(1)),
                sp(Cat::Sample, "sample", 0.5, 0.6, Some(1)),
                sp(Cat::Execute, "decode_b1", 0.6, 0.8, Some(1)),
                sp(Cat::Sample, "sample", 0.8, 0.9, Some(1)),
                sp(Cat::Decode, "step", 0.6, 0.9, Some(1)), // wrapper
            ],
            workers: vec![(1, "w".into())],
        };
        let agg = Aggregate::from_trace(&tr);
        assert_eq!(agg.span_count, 6);
        assert!((agg.per_category.get("Execute") - 0.6).abs() < 1e-9);
        assert!((agg.per_category.get("Sample") - 0.2).abs() < 1e-9);
        assert_eq!(agg.per_category.get("Decode"), 0.0);
        assert!((agg.per_stage.get("prefill_b32") - 0.4).abs() < 1e-9);
        assert!((agg.per_stage.get("decode_b1") - 0.2).abs() < 1e-9);
        // ttft: first span at 0.0, first sample ends 0.6 → 600 ms
        assert_eq!(agg.ttft_ms.len(), 1);
        assert!((agg.ttft_ms.mean() - 600.0).abs() < 1e-6);
        // tbt: 0.9 - 0.6 → 300 ms
        assert_eq!(agg.tbt_ms.len(), 1);
        assert!((agg.tbt_ms.mean() - 300.0).abs() < 1e-6);
    }

    #[test]
    fn renders_sorted_tables() {
        let tr = Trace {
            spans: vec![
                sp(Cat::Execute, "big", 0.0, 1.0, None),
                sp(Cat::Execute, "small", 1.0, 1.1, None),
            ],
            workers: vec![],
        };
        let agg = Aggregate::from_trace(&tr);
        let s = agg.render_stages();
        let big = s.find("big").unwrap();
        let small = s.find("small").unwrap();
        assert!(big < small, "largest stage first");
        assert!(agg.render_categories().contains("Execute"));
    }

    /// Regression: a request killed before its first sampled token
    /// used to disappear from the aggregate entirely; now it is
    /// counted, without panicking, and surfaced in the summary.
    #[test]
    fn sampleless_requests_are_counted_not_dropped() {
        let tr = Trace {
            spans: vec![
                // Request 1 completes normally.
                sp(Cat::Tokenize, "tokenize", 0.0, 0.1, Some(1)),
                sp(Cat::Sample, "sample", 0.1, 0.2, Some(1)),
                // Request 2 died mid-prefill: spans, but no Sample.
                sp(Cat::Tokenize, "tokenize", 0.0, 0.1, Some(2)),
                sp(Cat::Execute, "prefill_b8", 0.1, 0.4, Some(2)),
            ],
            workers: vec![(1, "w".into())],
        };
        let agg = Aggregate::from_trace(&tr);
        assert_eq!(agg.incomplete_requests, 1);
        assert_eq!(agg.ttft_ms.len(), 1, "completed request still folds");
        assert!(agg
            .latency_summary()
            .contains("incomplete requests (no sampled token): 1"));
        // Fully-sampled traces report zero and keep the old summary.
        let done = Aggregate::from_trace(&Trace::default());
        assert_eq!(done.incomplete_requests, 0);
        assert!(!done.latency_summary().contains("incomplete"));
    }

    #[test]
    fn empty_trace_is_safe() {
        let agg = Aggregate::from_trace(&Trace::default());
        assert_eq!(agg.span_count, 0);
        assert_eq!(agg.ttft_ms.len(), 0);
        assert!(agg.latency_summary().contains("n=0"));
    }
}
