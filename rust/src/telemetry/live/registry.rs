//! Lock-light labeled metrics registry.
//!
//! [`LiveMetrics`] is a cloneable process-level handle (the live
//! analogue of `Tracer`): series are registered once on a cold path
//! (mutex-protected maps keyed by [`Series`]) and updated through
//! cheap cached handles — [`Counter`]/[`Gauge`] are one relaxed
//! atomic op per update, [`QuantileSketch`] a handful. Disabled mode
//! is the tracer's contract: one relaxed atomic load and nothing
//! else, so `LiveMetrics::off()` on the serving path costs nothing
//! measurable (asserted by `benches/telemetry_overhead.rs`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use super::sketch::{QuantileSketch, SketchSnapshot};

/// A metric identity: name plus sorted label pairs. Ordering is
/// lexicographic, which gives the registry (and the Prometheus
/// exposition) a stable, deterministic series order.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Series {
    pub name: String,
    pub labels: Vec<(String, String)>,
}

impl Series {
    pub fn new(name: &str, labels: &[(&str, &str)]) -> Series {
        let mut ls: Vec<(String, String)> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        ls.sort();
        Series { name: name.to_string(), labels: ls }
    }

    /// `name{k="v",…}` (no braces when unlabeled) — the exposition
    /// and dashboard key format.
    pub fn render(&self) -> String {
        if self.labels.is_empty() {
            return self.name.clone();
        }
        let body: Vec<String> = self
            .labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
            .collect();
        format!("{}{{{}}}", self.name, body.join(","))
    }

    /// The value of one label (the dashboard's group-by accessor).
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// Prometheus label-value escaping: backslash, quote, newline.
pub(crate) fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Cached handle to a monotonically increasing counter.
#[derive(Debug, Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self, delta: u64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Cached handle to an f64 gauge (last-write-wins).
#[derive(Debug, Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

#[derive(Debug)]
struct Core {
    enabled: AtomicBool,
    counters: Mutex<BTreeMap<Series, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<Series, Arc<AtomicU64>>>,
    sketches: Mutex<BTreeMap<Series, Arc<QuantileSketch>>>,
}

/// Process-level live-metrics handle (cheap to clone; `Send + Sync`).
#[derive(Debug, Clone)]
pub struct LiveMetrics {
    core: Arc<Core>,
}

impl LiveMetrics {
    /// An enabled registry.
    pub fn new() -> Self {
        Self::with_enabled(true)
    }

    /// A disabled registry: every publish is one relaxed atomic load.
    pub fn off() -> Self {
        Self::with_enabled(false)
    }

    fn with_enabled(on: bool) -> Self {
        LiveMetrics {
            core: Arc::new(Core {
                enabled: AtomicBool::new(on),
                counters: Mutex::new(BTreeMap::new()),
                gauges: Mutex::new(BTreeMap::new()),
                sketches: Mutex::new(BTreeMap::new()),
            }),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.core.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.core.enabled.store(on, Ordering::Relaxed);
    }

    /// A worker panicking mid-update must degrade metrics, never take
    /// down the publisher: recover the poisoned map.
    fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
        m.lock().unwrap_or_else(|poison| poison.into_inner())
    }

    /// Register (or fetch) a counter series; cache the handle on hot
    /// paths. Registration works while disabled so handles obtained
    /// early keep working after `set_enabled(true)`.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let series = Series::new(name, labels);
        let mut map = Self::lock(&self.core.counters);
        Counter(
            map.entry(series)
                .or_insert_with(|| Arc::new(AtomicU64::new(0)))
                .clone(),
        )
    }

    /// Register (or fetch) a gauge series.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        let series = Series::new(name, labels);
        let mut map = Self::lock(&self.core.gauges);
        Gauge(
            map.entry(series)
                .or_insert_with(|| {
                    Arc::new(AtomicU64::new(0f64.to_bits()))
                })
                .clone(),
        )
    }

    /// Register (or fetch) a quantile-sketch series (TTFT/TBT style
    /// latency distributions).
    pub fn sketch(&self, name: &str, labels: &[(&str, &str)])
                  -> Arc<QuantileSketch> {
        let series = Series::new(name, labels);
        let mut map = Self::lock(&self.core.sketches);
        map.entry(series)
            .or_insert_with(|| Arc::new(QuantileSketch::new()))
            .clone()
    }

    /// Cold-path counter bump (registry lookup per call). Disabled:
    /// one relaxed load.
    pub fn inc(&self, name: &str, labels: &[(&str, &str)], delta: u64) {
        if !self.is_enabled() {
            return;
        }
        self.counter(name, labels).inc(delta);
    }

    /// Cold-path gauge write. Disabled: one relaxed load.
    pub fn set_gauge(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        if !self.is_enabled() {
            return;
        }
        self.gauge(name, labels).set(v);
    }

    /// Cold-path sketch observation. Disabled: one relaxed load.
    pub fn observe(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        if !self.is_enabled() {
            return;
        }
        self.sketch(name, labels).record(v);
    }

    /// Consistent point-in-time copy of every series, in stable
    /// (name, labels) order — the input to the Prometheus renderer
    /// and the dashboard tables.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = Self::lock(&self.core.counters)
            .iter()
            .map(|(s, c)| (s.clone(), c.load(Ordering::Relaxed)))
            .collect();
        let gauges = Self::lock(&self.core.gauges)
            .iter()
            .map(|(s, g)| {
                (s.clone(), f64::from_bits(g.load(Ordering::Relaxed)))
            })
            .collect();
        let sketches = Self::lock(&self.core.sketches)
            .iter()
            .map(|(s, q)| (s.clone(), q.snapshot()))
            .collect();
        MetricsSnapshot { counters, gauges, sketches }
    }
}

impl Default for LiveMetrics {
    fn default() -> Self {
        LiveMetrics::new()
    }
}

/// Everything the registry knew at one instant.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub counters: Vec<(Series, u64)>,
    pub gauges: Vec<(Series, f64)>,
    pub sketches: Vec<(Series, SketchSnapshot)>,
}

impl MetricsSnapshot {
    pub fn counter(&self, name: &str, labels: &[(&str, &str)])
                   -> Option<u64> {
        let key = Series::new(name, labels);
        self.counters
            .iter()
            .find(|(s, _)| *s == key)
            .map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)])
                 -> Option<f64> {
        let key = Series::new(name, labels);
        self.gauges
            .iter()
            .find(|(s, _)| *s == key)
            .map(|(_, v)| *v)
    }

    pub fn sketch(&self, name: &str, labels: &[(&str, &str)])
                  -> Option<&SketchSnapshot> {
        let key = Series::new(name, labels);
        self.sketches
            .iter()
            .find(|(s, _)| *s == key)
            .map(|(_, v)| v)
    }

    /// Merge every sketch series named `name` whose `by` label equals
    /// `value` — the dashboard's row aggregator (e.g. all tenants of
    /// one replica, or all replicas of one tenant).
    pub fn merged_sketch(&self, name: &str, by: &str, value: &str)
                         -> SketchSnapshot {
        let mut out = SketchSnapshot::empty();
        for (s, snap) in &self.sketches {
            if s.name == name && s.label(by) == Some(value) {
                out.merge(snap);
            }
        }
        out
    }

    /// Distinct values of label `by` across sketch series named
    /// `name`, sorted (the dashboard's row key enumerator).
    pub fn sketch_label_values(&self, name: &str, by: &str)
                               -> Vec<String> {
        let mut vals: Vec<String> = self
            .sketches
            .iter()
            .filter(|(s, _)| s.name == name)
            .filter_map(|(s, _)| s.label(by).map(|v| v.to_string()))
            .collect();
        vals.sort();
        vals.dedup();
        vals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::prop_check;
    use crate::substrate::rng::Rng;

    #[test]
    fn series_sorts_labels_and_renders() {
        let a = Series::new("m", &[("b", "2"), ("a", "1")]);
        let b = Series::new("m", &[("a", "1"), ("b", "2")]);
        assert_eq!(a, b);
        assert_eq!(a.render(), "m{a=\"1\",b=\"2\"}");
        assert_eq!(Series::new("bare", &[]).render(), "bare");
        assert_eq!(a.label("b"), Some("2"));
        assert_eq!(a.label("c"), None);
        let esc = Series::new("m", &[("p", "a\"b\\c")]);
        assert_eq!(esc.render(), "m{p=\"a\\\"b\\\\c\"}");
    }

    #[test]
    fn counters_gauges_sketches_roundtrip() {
        let m = LiveMetrics::new();
        let c = m.counter("mmserve_ticks_total", &[("replica", "0")]);
        c.inc(3);
        c.inc(2);
        // Second registration returns the same underlying cell.
        m.counter("mmserve_ticks_total", &[("replica", "0")]).inc(1);
        let g = m.gauge("mmserve_queue_depth", &[("replica", "0")]);
        g.set(7.5);
        m.observe("mmserve_ttft_ms", &[("replica", "0")], 12.0);
        m.observe("mmserve_ttft_ms", &[("replica", "0")], 14.0);
        let snap = m.snapshot();
        assert_eq!(
            snap.counter("mmserve_ticks_total", &[("replica", "0")]),
            Some(6)
        );
        assert_eq!(
            snap.gauge("mmserve_queue_depth", &[("replica", "0")]),
            Some(7.5)
        );
        let sk = snap
            .sketch("mmserve_ttft_ms", &[("replica", "0")])
            .unwrap();
        assert_eq!(sk.count, 2);
        assert!(snap.counter("missing", &[]).is_none());
    }

    #[test]
    fn disabled_mode_publishes_nothing() {
        let m = LiveMetrics::off();
        assert!(!m.is_enabled());
        m.inc("c", &[], 5);
        m.set_gauge("g", &[], 1.0);
        m.observe("s", &[], 2.0);
        let snap = m.snapshot();
        assert!(snap.counters.is_empty());
        assert!(snap.gauges.is_empty());
        assert!(snap.sketches.is_empty());
        // Handles registered while disabled survive an enable flip.
        let c = m.counter("late", &[]);
        m.set_enabled(true);
        c.inc(1);
        assert_eq!(m.snapshot().counter("late", &[]), Some(1));
    }

    #[test]
    fn merged_sketch_groups_by_label() {
        let m = LiveMetrics::new();
        for (r, t, v) in [("0", "a", 10.0), ("0", "b", 20.0),
                          ("1", "a", 30.0)] {
            m.observe("mmserve_tbt_ms",
                      &[("replica", r), ("tenant", t)], v);
        }
        let snap = m.snapshot();
        let r0 = snap.merged_sketch("mmserve_tbt_ms", "replica", "0");
        assert_eq!(r0.count, 2);
        assert_eq!(r0.min(), 10.0);
        assert_eq!(r0.max(), 20.0);
        let ta = snap.merged_sketch("mmserve_tbt_ms", "tenant", "a");
        assert_eq!(ta.count, 2);
        assert_eq!(ta.max(), 30.0);
        assert_eq!(snap.sketch_label_values("mmserve_tbt_ms", "tenant"),
                   vec!["a".to_string(), "b".to_string()]);
        assert_eq!(snap.sketch_label_values("mmserve_tbt_ms", "replica"),
                   vec!["0".to_string(), "1".to_string()]);
    }

    /// Satellite: concurrent publishers + a snapshotting reader never
    /// lose an update and never tear — counters sum exactly, sketch
    /// counts match, and mid-run snapshots are internally consistent
    /// (monotone counter reads).
    #[test]
    fn prop_concurrent_publish_snapshot_is_lossless() {
        use std::sync::Arc;
        prop_check(
            8,
            4242,
            |r: &mut Rng| (r.usize(2, 4), r.usize(200, 800)),
            |&(threads, per_thread)| {
                let m = Arc::new(LiveMetrics::new());
                let mut handles = Vec::new();
                for t in 0..threads {
                    let m = m.clone();
                    handles.push(std::thread::spawn(move || {
                        let label = t.to_string();
                        let c = m.counter("hits",
                                          &[("replica", label.as_str())]);
                        let s = m.sketch("lat",
                                         &[("replica", label.as_str())]);
                        for i in 0..per_thread {
                            c.inc(1);
                            s.record(1.0 + i as f64);
                        }
                    }));
                }
                // Reader thread: snapshots must be monotone per series.
                let reader = {
                    let m = m.clone();
                    std::thread::spawn(move || {
                        let mut last = 0u64;
                        for _ in 0..50 {
                            let snap = m.snapshot();
                            let total: u64 = snap
                                .counters
                                .iter()
                                .map(|(_, v)| v)
                                .sum();
                            if total < last {
                                return Err(format!(
                                    "counter sum went backwards: \
                                     {total} < {last}"
                                ));
                            }
                            last = total;
                        }
                        Ok(())
                    })
                };
                for h in handles {
                    h.join().map_err(|_| "publisher panicked")?;
                }
                reader.join().map_err(|_| "reader panicked")??;
                let snap = m.snapshot();
                let total: u64 =
                    snap.counters.iter().map(|(_, v)| v).sum();
                let want = (threads * per_thread) as u64;
                if total != want {
                    return Err(format!(
                        "lost counter updates: {total} != {want}"
                    ));
                }
                let sk_total: u64 =
                    snap.sketches.iter().map(|(_, s)| s.count).sum();
                if sk_total != want {
                    return Err(format!(
                        "lost sketch updates: {sk_total} != {want}"
                    ));
                }
                Ok(())
            },
        );
    }
}
