//! Prometheus text exposition for [`MetricsSnapshot`].
//!
//! Renders the classic text format: one `# TYPE` line per metric
//! name, then one sample line per series. Counters and gauges map
//! directly; quantile sketches render as a `summary` — p50/p90/p99
//! `quantile`-labeled lines plus `_sum`/`_count` — so a scrape (or
//! the `--metrics-out` file) carries the same SLO percentiles the
//! dashboard tables show. Series order is the snapshot's stable
//! (name, sorted-labels) order, making output diffable across runs.

use std::path::Path;

use super::registry::{escape_label, MetricsSnapshot, Series};

/// Quantiles exported for every sketch series.
pub const SUMMARY_QUANTILES: [(f64, &str); 3] =
    [(50.0, "0.5"), (90.0, "0.9"), (99.0, "0.99")];

fn label_body(series: &Series, extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = series
        .labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn type_line(out: &mut String, last: &mut String, name: &str,
             kind: &str) {
    if last != name {
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        last.clear();
        last.push_str(name);
    }
}

/// Render a snapshot as Prometheus text exposition.
pub fn render(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let mut last = String::new();
    for (series, v) in &snap.counters {
        type_line(&mut out, &mut last, &series.name, "counter");
        out.push_str(&format!(
            "{}{} {v}\n",
            series.name,
            label_body(series, None)
        ));
    }
    last.clear();
    for (series, v) in &snap.gauges {
        type_line(&mut out, &mut last, &series.name, "gauge");
        out.push_str(&format!(
            "{}{} {v}\n",
            series.name,
            label_body(series, None)
        ));
    }
    last.clear();
    for (series, sk) in &snap.sketches {
        type_line(&mut out, &mut last, &series.name, "summary");
        for (p, q) in SUMMARY_QUANTILES {
            out.push_str(&format!(
                "{}{} {}\n",
                series.name,
                label_body(series, Some(("quantile", q))),
                sk.percentile(p)
            ));
        }
        out.push_str(&format!(
            "{}_sum{} {}\n",
            series.name,
            label_body(series, None),
            sk.sum
        ));
        out.push_str(&format!(
            "{}_count{} {}\n",
            series.name,
            label_body(series, None),
            sk.count
        ));
    }
    out
}

/// Render and write a snapshot to `path` (the `--metrics-out` sink;
/// whole-file replace so each tick's snapshot is self-consistent).
pub fn write_file(snap: &MetricsSnapshot, path: &Path)
                  -> std::io::Result<()> {
    std::fs::write(path, render(snap))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::live::registry::LiveMetrics;

    fn sample_snapshot() -> MetricsSnapshot {
        let m = LiveMetrics::new();
        m.inc("mmserve_ticks_total", &[("replica", "0")], 12);
        m.inc("mmserve_ticks_total", &[("replica", "1")], 9);
        m.set_gauge("mmserve_queue_depth", &[("replica", "0")], 3.5);
        for v in [10.0, 20.0, 30.0, 40.0] {
            m.observe("mmserve_ttft_ms",
                      &[("replica", "0"), ("tenant", "a")], v);
        }
        m.snapshot()
    }

    #[test]
    fn renders_counters_gauges_and_summaries() {
        let text = render(&sample_snapshot());
        assert!(text.contains("# TYPE mmserve_ticks_total counter\n"));
        assert!(text.contains("mmserve_ticks_total{replica=\"0\"} 12\n"));
        assert!(text.contains("mmserve_ticks_total{replica=\"1\"} 9\n"));
        assert!(text.contains("# TYPE mmserve_queue_depth gauge\n"));
        assert!(text.contains("mmserve_queue_depth{replica=\"0\"} 3.5\n"));
        assert!(text.contains("# TYPE mmserve_ttft_ms summary\n"));
        assert!(text.contains(
            "mmserve_ttft_ms{replica=\"0\",tenant=\"a\",quantile=\"0.5\"} "
        ));
        assert!(text.contains(
            "mmserve_ttft_ms_sum{replica=\"0\",tenant=\"a\"} 100\n"
        ));
        assert!(text.contains(
            "mmserve_ttft_ms_count{replica=\"0\",tenant=\"a\"} 4\n"
        ));
        // One TYPE line per metric name, not per series.
        assert_eq!(
            text.matches("# TYPE mmserve_ticks_total counter").count(),
            1
        );
    }

    #[test]
    fn every_sample_line_is_well_formed() {
        let text = render(&sample_snapshot());
        assert!(!text.is_empty());
        for line in text.lines() {
            if line.starts_with("# TYPE ") {
                assert_eq!(line.split_whitespace().count(), 4, "{line}");
                continue;
            }
            // `name{labels} value` — value parses as f64.
            let (_, value) = line.rsplit_once(' ')
                .unwrap_or_else(|| panic!("no value in {line:?}"));
            value.parse::<f64>()
                .unwrap_or_else(|_| panic!("bad value in {line:?}"));
        }
    }

    #[test]
    fn label_values_are_escaped() {
        let m = LiveMetrics::new();
        m.set_gauge("g", &[("model", "a\"b\\c\nd")], 1.0);
        let text = render(&m.snapshot());
        assert!(text.contains("g{model=\"a\\\"b\\\\c\\nd\"} 1\n"));
    }

    #[test]
    fn unlabeled_series_render_bare() {
        let m = LiveMetrics::new();
        m.inc("up_total", &[], 1);
        let text = render(&m.snapshot());
        assert!(text.contains("# TYPE up_total counter\nup_total 1\n"));
    }

    #[test]
    fn write_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!(
            "mmserve_prom_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.prom");
        let snap = sample_snapshot();
        write_file(&snap, &path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body, render(&snap));
        // Whole-file replace, not append.
        write_file(&snap, &path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), body);
        let _ = std::fs::remove_file(&path);
    }
}
