//! Per-tick fleet sampling and online idle-gap attribution.
//!
//! [`WorkerSampler`] is the publication point a replica worker (or
//! replay driver) calls once per scheduler tick: it turns the pool's
//! cumulative [`PoolStats`], the per-shard [`ShardView`]s, and the
//! queue depth into labeled registry series (cumulative counters
//! become deltas against the previous tick, point-in-time values
//! become gauges), feeds the flight recorder one structured event per
//! tick, and watches for preemption storms and SIGTERM. When both the
//! registry and the recorder are disabled a sample is two relaxed
//! atomic loads — the tracer's contract.
//!
//! [`OnlineAttribution`] is the incremental counterpart of
//! [`Attribution::from_trace`]: the same gap classification, folded
//! span-batch by span-batch (one batch per tick via
//! `WorkerTracer::spans_since`) instead of over a retained
//! whole-run trace, so `mmserve_idle_gap_ms` is queryable mid-run.

use std::collections::BTreeMap;

use crate::kvpool::{PoolStats, ShardView};
use crate::substrate::json::Json;
use crate::substrate::metrics::OpTimes;
use crate::telemetry::attribution::{gap_label, Attribution,
                                    GAP_CATEGORIES};
use crate::telemetry::tracer::{Cat, Span};

use super::recorder::FlightRecorder;
use super::registry::{Counter, Gauge, LiveMetrics};

/// The exported metric vocabulary — `ci/check_metrics.py` validates
/// the Prometheus exposition against these names.
pub const TICKS_TOTAL: &str = "mmserve_ticks_total";
pub const QUEUE_DEPTH: &str = "mmserve_queue_depth";
pub const PREFIX_HIT_RATE: &str = "mmserve_prefix_hit_rate";
pub const PREFIX_LOOKUPS_TOTAL: &str = "mmserve_prefix_lookups_total";
pub const PREFIX_HITS_TOTAL: &str = "mmserve_prefix_hits_total";
pub const CAPACITY_WAIT_TICKS_TOTAL: &str =
    "mmserve_capacity_wait_ticks_total";
pub const PREEMPTIONS_TOTAL: &str = "mmserve_preemptions_total";
pub const EVICTIONS_TOTAL: &str = "mmserve_evictions_total";
pub const SHARD_SPILLS_TOTAL: &str = "mmserve_shard_spills_total";
pub const LIVE_PAGES: &str = "mmserve_live_pages";
pub const FREE_PAGES: &str = "mmserve_free_pages";
pub const CACHED_PAGES: &str = "mmserve_cached_pages";
pub const REQUESTS_COMPLETED_TOTAL: &str =
    "mmserve_requests_completed_total";
pub const TOKENS_DECODED_TOTAL: &str = "mmserve_tokens_decoded_total";
pub const TTFT_MS: &str = "mmserve_ttft_ms";
pub const TBT_MS: &str = "mmserve_tbt_ms";
/// Router-side: requests handed to each replica (`model`, `replica`).
pub const ROUTED_TOTAL: &str = "mmserve_routed_total";
/// Batcher-side: arrivals into a replica's FCFS queue (`replica`).
pub const ENQUEUED_TOTAL: &str = "mmserve_enqueued_total";
/// Batcher-side: requests admitted to prefill (`replica`).
pub const ADMITTED_TOTAL: &str = "mmserve_admitted_total";
pub const IDLE_GAP_MS: &str = "mmserve_idle_gap_ms";
pub const EXECUTE_MS: &str = "mmserve_execute_ms";

struct ShardGauges {
    live_pages: Gauge,
    free_pages: Gauge,
    cached_pages: Gauge,
}

/// One replica's per-tick publication point (cheap cached handles;
/// own one per worker thread).
pub struct WorkerSampler {
    live: LiveMetrics,
    recorder: FlightRecorder,
    replica: String,
    ticks: Counter,
    queue_depth: Gauge,
    hit_rate: Gauge,
    prefix_lookups: Counter,
    prefix_hits: Counter,
    capacity_waits: Counter,
    preemptions: Counter,
    evictions: Counter,
    spills: Counter,
    requests: Counter,
    tokens: Counter,
    shard_gauges: Vec<ShardGauges>,
    prev: PoolStats,
    prev_completed: u64,
    prev_tokens: u64,
}

impl WorkerSampler {
    pub fn new(live: LiveMetrics, recorder: FlightRecorder,
               replica: usize) -> Self {
        let replica = replica.to_string();
        let l = &[("replica", replica.as_str())];
        WorkerSampler {
            ticks: live.counter(TICKS_TOTAL, l),
            queue_depth: live.gauge(QUEUE_DEPTH, l),
            hit_rate: live.gauge(PREFIX_HIT_RATE, l),
            prefix_lookups: live.counter(PREFIX_LOOKUPS_TOTAL, l),
            prefix_hits: live.counter(PREFIX_HITS_TOTAL, l),
            capacity_waits: live.counter(CAPACITY_WAIT_TICKS_TOTAL, l),
            preemptions: live.counter(PREEMPTIONS_TOTAL, l),
            evictions: live.counter(EVICTIONS_TOTAL, l),
            spills: live.counter(SHARD_SPILLS_TOTAL, l),
            requests: live.counter(REQUESTS_COMPLETED_TOTAL, l),
            tokens: live.counter(TOKENS_DECODED_TOTAL, l),
            shard_gauges: Vec::new(),
            prev: PoolStats::default(),
            prev_completed: 0,
            prev_tokens: 0,
            live,
            recorder,
            replica,
        }
    }

    /// A sampler that publishes nowhere (both planes disabled).
    pub fn disabled(replica: usize) -> Self {
        Self::new(LiveMetrics::off(), FlightRecorder::disabled(),
                  replica)
    }

    pub fn replica(&self) -> &str {
        &self.replica
    }

    pub fn live(&self) -> &LiveMetrics {
        &self.live
    }

    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    /// Publish one scheduler tick: cumulative `stats` counters become
    /// per-tick deltas, point-in-time state becomes gauges, and the
    /// flight recorder gets one structured event. Two relaxed atomic
    /// loads when both planes are disabled.
    pub fn sample_tick(&mut self, tick: u64, queue_depth: usize,
                       stats: &PoolStats, shards: &[ShardView]) {
        let live_on = self.live.is_enabled();
        let rec_on = self.recorder.is_enabled();
        if !live_on && !rec_on {
            return;
        }
        let d_lookups =
            stats.prefix_lookups.saturating_sub(self.prev.prefix_lookups);
        let d_hits =
            stats.prefix_hits.saturating_sub(self.prev.prefix_hits);
        let d_waits = stats
            .capacity_wait_ticks
            .saturating_sub(self.prev.capacity_wait_ticks);
        let d_preempt =
            stats.preemptions.saturating_sub(self.prev.preemptions);
        let d_evict =
            stats.evictions.saturating_sub(self.prev.evictions);
        let d_spills =
            stats.shard_spills.saturating_sub(self.prev.shard_spills);
        let live_pages: usize =
            shards.iter().map(|s| s.live_pages).sum();
        if live_on {
            self.ticks.inc(1);
            self.queue_depth.set(queue_depth as f64);
            self.hit_rate.set(stats.hit_rate());
            self.prefix_lookups.inc(d_lookups);
            self.prefix_hits.inc(d_hits);
            self.capacity_waits.inc(d_waits);
            self.preemptions.inc(d_preempt);
            self.evictions.inc(d_evict);
            self.spills.inc(d_spills);
            for (i, sv) in shards.iter().enumerate() {
                if self.shard_gauges.len() <= i {
                    let shard = i.to_string();
                    let labels = &[("replica", self.replica.as_str()),
                                   ("shard", shard.as_str())];
                    self.shard_gauges.push(ShardGauges {
                        live_pages: self.live.gauge(LIVE_PAGES, labels),
                        free_pages: self.live.gauge(FREE_PAGES, labels),
                        cached_pages: self
                            .live
                            .gauge(CACHED_PAGES, labels),
                    });
                }
                let g = &self.shard_gauges[i];
                g.live_pages.set(sv.live_pages as f64);
                g.free_pages.set(sv.free_pages as f64);
                g.cached_pages.set(sv.cached_pages as f64);
            }
        }
        if rec_on {
            self.recorder.poll_sigterm();
            self.recorder.record(Json::from_obj(vec![
                ("kind".into(), Json::Str("tick".into())),
                ("replica".into(),
                 Json::Str(self.replica.clone())),
                ("tick".into(), Json::Num(tick as f64)),
                ("queue_depth".into(),
                 Json::Num(queue_depth as f64)),
                ("live_pages".into(), Json::Num(live_pages as f64)),
                ("hit_rate".into(), Json::Num(stats.hit_rate())),
                ("capacity_waits".into(), Json::Num(d_waits as f64)),
                ("preemptions".into(), Json::Num(d_preempt as f64)),
                ("evictions".into(), Json::Num(d_evict as f64)),
                ("spills".into(), Json::Num(d_spills as f64)),
            ]));
            self.recorder.note_preemptions(&self.replica, d_preempt);
        }
        self.prev = stats.clone();
    }

    /// Record a completed request's time-to-first-token (SLO sketch,
    /// per replica × tenant).
    pub fn observe_ttft_ms(&self, tenant: &str, ms: f64) {
        self.live.observe(
            TTFT_MS,
            &[("replica", self.replica.as_str()), ("tenant", tenant)],
            ms,
        );
    }

    /// Record one inter-token gap (time-between-tokens).
    pub fn observe_tbt_ms(&self, tenant: &str, ms: f64) {
        self.live.observe(
            TBT_MS,
            &[("replica", self.replica.as_str()), ("tenant", tenant)],
            ms,
        );
    }

    /// Count a finished request and its decoded tokens.
    pub fn note_completion(&self, decoded_tokens: u64) {
        if !self.live.is_enabled() {
            return;
        }
        self.requests.inc(1);
        self.tokens.inc(decoded_tokens);
    }

    /// Publish run-total progress counters (cumulative inputs; the
    /// sampler turns them into counter deltas) — the replay drivers'
    /// batch alternative to per-request [`WorkerSampler::note_completion`].
    pub fn note_progress(&mut self, completed_total: u64,
                         tokens_total: u64) {
        if !self.live.is_enabled() {
            return;
        }
        self.requests
            .inc(completed_total.saturating_sub(self.prev_completed));
        self.tokens
            .inc(tokens_total.saturating_sub(self.prev_tokens));
        self.prev_completed = completed_total;
        self.prev_tokens = tokens_total;
    }
}

#[derive(Debug, Default)]
struct TidFold {
    /// First observed dispatch start (the wall window's left edge).
    w0: Option<f64>,
    /// Right edge of the execute union so far.
    cursor: f64,
    /// Attributable host spans that may still overlap a future gap,
    /// t0-ordered.
    pending: Vec<(f64, f64, &'static str)>,
}

/// Incremental idle-gap attribution: feed it span batches as they
/// complete and read [`OnlineAttribution::snapshot`] at any tick.
///
/// Matches [`Attribution::from_trace`] exactly when batches are taken
/// at span-quiescent points (no span open across the batch boundary —
/// true for `WorkerTracer::spans_since` called between scheduler
/// ticks), since then every host span overlapping a gap has completed
/// by the time the gap's closing dispatch is folded.
#[derive(Debug, Default)]
pub struct OnlineAttribution {
    tids: BTreeMap<u64, TidFold>,
    gaps: OpTimes,
    execute: f64,
}

impl OnlineAttribution {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one batch of completed spans (any worker mix; grouped by
    /// `tid` internally, processed in start-time order).
    pub fn observe(&mut self, spans: &[Span]) {
        let mut order: Vec<&Span> = spans.iter().collect();
        order.sort_by(|a, b| a.t0.total_cmp(&b.t0));
        for s in order {
            self.observe_span(s);
        }
    }

    /// Fold a single completed span (callers batching per tick should
    /// prefer [`OnlineAttribution::observe`], which restores
    /// start-time order within the batch).
    pub fn observe_span(&mut self, s: &Span) {
        if s.cat == Cat::Execute {
            let st = self.tids.entry(s.tid).or_default();
            if st.w0.is_none() {
                st.w0 = Some(s.t0);
                st.cursor = s.t0;
            }
            if s.t0 > st.cursor {
                classify_gap(st.cursor, s.t0, &st.pending,
                             &mut self.gaps);
            }
            self.execute += (s.t1 - s.t0.max(st.cursor)).max(0.0);
            st.cursor = st.cursor.max(s.t1);
            let cursor = st.cursor;
            st.pending.retain(|&(_, h1, _)| h1 > cursor);
        } else if let Some(label) = gap_label(s.cat) {
            let st = self.tids.entry(s.tid).or_default();
            st.pending.push((s.t0, s.t1, label));
        }
    }

    /// The attribution accumulated so far, in the same shape the
    /// post-hoc pass produces (all buckets present; wall = per-worker
    /// dispatch windows summed).
    pub fn snapshot(&self) -> Attribution {
        let mut gaps = self.gaps.clone();
        for key in GAP_CATEGORIES {
            gaps.add(key, 0.0);
        }
        let wall = self
            .tids
            .values()
            .filter_map(|st| st.w0.map(|w0| st.cursor - w0))
            .sum();
        Attribution { execute: self.execute, gaps, wall }
    }

    /// Publish the current buckets as per-replica gauges
    /// (`mmserve_idle_gap_ms{replica,bucket}` + execute time).
    pub fn publish(&self, live: &LiveMetrics, replica: &str) {
        if !live.is_enabled() {
            return;
        }
        let a = self.snapshot();
        for key in GAP_CATEGORIES {
            live.set_gauge(
                IDLE_GAP_MS,
                &[("bucket", key), ("replica", replica)],
                a.gaps.get(key) * 1e3,
            );
        }
        live.set_gauge(EXECUTE_MS, &[("replica", replica)],
                       a.execute * 1e3);
    }
}

/// The per-gap sweep of `Attribution::accumulate_tid`, applied to one
/// gap: host work claims its overlap in start order, uncovered
/// remainder goes to `Other`. `pending` must be t0-ordered.
fn classify_gap(g0: f64, g1: f64, pending: &[(f64, f64, &'static str)],
                gaps: &mut OpTimes) {
    let mut p = g0;
    for &(h0, h1, label) in pending {
        if h0 >= g1 {
            break;
        }
        if h1 <= p {
            continue;
        }
        let start = h0.max(p);
        if start > p {
            gaps.add("Other", start - p);
            p = start;
        }
        let end = h1.min(g1);
        if end > p {
            gaps.add(label, end - p);
            p = end;
        }
        if p >= g1 {
            break;
        }
    }
    if p < g1 {
        gaps.add("Other", g1 - p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::tracer::Trace;

    fn sp(cat: Cat, t0: f64, t1: f64) -> Span {
        sp_on(cat, t0, t1, 1)
    }

    fn sp_on(cat: Cat, t0: f64, t1: f64, tid: u64) -> Span {
        Span { name: cat.as_str().to_string(), cat, t0, t1, tid,
               req: None, tick: None }
    }

    fn assert_matches_posthoc(spans: Vec<Span>) {
        let trace = Trace {
            spans: spans.clone(),
            workers: vec![(1, "w".into())],
        };
        let posthoc = Attribution::from_trace(&trace);
        let mut online = OnlineAttribution::new();
        online.observe(&spans);
        let got = online.snapshot();
        assert!((got.wall - posthoc.wall).abs() < 1e-9,
                "wall {} vs {}", got.wall, posthoc.wall);
        assert!((got.execute - posthoc.execute).abs() < 1e-9,
                "execute {} vs {}", got.execute, posthoc.execute);
        for key in GAP_CATEGORIES {
            assert!(
                (got.gaps.get(key) - posthoc.gaps.get(key)).abs()
                    < 1e-9,
                "{key}: online {} vs post-hoc {}",
                got.gaps.get(key),
                posthoc.gaps.get(key)
            );
        }
    }

    #[test]
    fn online_fold_matches_posthoc_attribution() {
        assert_matches_posthoc(vec![
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::Schedule, 1.0, 1.3),
            sp(Cat::Tokenize, 1.3, 1.5),
            sp(Cat::Sample, 1.5, 1.7),
            sp(Cat::Upload, 1.7, 1.9),
            sp(Cat::Execute, 2.0, 3.0),
        ]);
        // Wrapper spanning two gaps (the chunked-prefill shape).
        assert_matches_posthoc(vec![
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::PrefillStall, 1.0, 3.0),
            sp(Cat::Tokenize, 1.0, 1.2),
            sp(Cat::Execute, 1.5, 2.5),
            sp(Cat::Execute, 3.0, 4.0),
        ]);
        // Host work overlapping execute attributes nothing.
        assert_matches_posthoc(vec![
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::Sample, 0.2, 0.4),
            sp(Cat::Execute, 1.0, 2.0),
        ]);
        // Multi-worker traces fold per tid.
        assert_matches_posthoc(vec![
            sp_on(Cat::Execute, 0.0, 1.0, 1),
            sp_on(Cat::KvWait, 1.0, 1.6, 1),
            sp_on(Cat::Execute, 2.0, 3.0, 1),
            sp_on(Cat::Execute, 0.5, 1.5, 2),
            sp_on(Cat::Sample, 1.5, 1.8, 2),
            sp_on(Cat::Execute, 2.0, 2.5, 2),
        ]);
    }

    #[test]
    fn per_tick_batches_equal_single_batch() {
        // Feeding tick-sized batches (span-quiescent boundaries) must
        // give the same answer as one big batch — the property the
        // per-tick `spans_since` wiring depends on.
        let ticks: Vec<Vec<Span>> = (0..20u64)
            .map(|i| {
                let t = i as f64;
                vec![
                    sp(Cat::Schedule, t, t + 0.1),
                    sp(Cat::KvWait, t + 0.1, t + 0.2),
                    sp(Cat::Execute, t + 0.3, t + 0.9),
                    sp(Cat::Sample, t + 0.9, t + 0.95),
                ]
            })
            .collect();
        let mut batched = OnlineAttribution::new();
        for tick in &ticks {
            batched.observe(tick);
        }
        let all: Vec<Span> =
            ticks.iter().flat_map(|t| t.iter().cloned()).collect();
        let mut whole = OnlineAttribution::new();
        whole.observe(&all);
        let (a, b) = (batched.snapshot(), whole.snapshot());
        assert!((a.wall - b.wall).abs() < 1e-9);
        assert!((a.execute - b.execute).abs() < 1e-9);
        for key in GAP_CATEGORIES {
            assert!((a.gaps.get(key) - b.gaps.get(key)).abs() < 1e-9,
                    "{key}");
        }
        assert_matches_posthoc(all);
    }

    #[test]
    fn publish_exports_all_buckets() {
        let live = LiveMetrics::new();
        let mut online = OnlineAttribution::new();
        online.observe(&[
            sp(Cat::Execute, 0.0, 1.0),
            sp(Cat::KvWait, 1.0, 1.5),
            sp(Cat::Execute, 2.0, 3.0),
        ]);
        online.publish(&live, "0");
        let snap = live.snapshot();
        let kv = snap
            .gauge(IDLE_GAP_MS,
                   &[("bucket", "KvCapacity"), ("replica", "0")])
            .unwrap();
        assert!((kv - 500.0).abs() < 1e-6);
        for key in GAP_CATEGORIES {
            assert!(
                snap.gauge(IDLE_GAP_MS,
                           &[("bucket", key), ("replica", "0")])
                    .is_some(),
                "{key} missing"
            );
        }
        assert!((snap.gauge(EXECUTE_MS, &[("replica", "0")]).unwrap()
                 - 2000.0)
                    .abs()
                    < 1e-6);
    }

    fn shard_view(shard: usize, live: usize, free: usize,
                  cached: usize) -> ShardView {
        ShardView {
            shard,
            total_pages: live + free + cached,
            free_pages: free,
            live_pages: live,
            cached_pages: cached,
        }
    }

    #[test]
    fn sampler_publishes_deltas_gauges_and_flight_events() {
        let live = LiveMetrics::new();
        let rec = FlightRecorder::new(16);
        let mut sampler =
            WorkerSampler::new(live.clone(), rec.clone(), 0);
        let mut stats = PoolStats {
            prefix_lookups: 10,
            prefix_hits: 4,
            capacity_wait_ticks: 1,
            ..PoolStats::default()
        };
        sampler.sample_tick(0, 3, &stats,
                            &[shard_view(0, 5, 3, 1),
                              shard_view(1, 2, 6, 0)]);
        stats.prefix_lookups = 25;
        stats.prefix_hits = 9;
        stats.capacity_wait_ticks = 3;
        stats.evictions = 2;
        sampler.sample_tick(1, 1, &stats,
                            &[shard_view(0, 6, 2, 1),
                              shard_view(1, 2, 6, 0)]);
        sampler.observe_ttft_ms("a", 12.5);
        sampler.observe_tbt_ms("a", 3.0);
        sampler.note_completion(40);
        let snap = live.snapshot();
        let r = &[("replica", "0")];
        assert_eq!(snap.counter(TICKS_TOTAL, r), Some(2));
        // Cumulative inputs arrive as cumulative outputs via deltas.
        assert_eq!(snap.counter(PREFIX_LOOKUPS_TOTAL, r), Some(25));
        assert_eq!(snap.counter(PREFIX_HITS_TOTAL, r), Some(9));
        assert_eq!(snap.counter(CAPACITY_WAIT_TICKS_TOTAL, r),
                   Some(3));
        assert_eq!(snap.counter(EVICTIONS_TOTAL, r), Some(2));
        assert_eq!(snap.gauge(QUEUE_DEPTH, r), Some(1.0));
        assert_eq!(
            snap.gauge(LIVE_PAGES,
                       &[("replica", "0"), ("shard", "0")]),
            Some(6.0)
        );
        assert_eq!(
            snap.gauge(FREE_PAGES,
                       &[("replica", "0"), ("shard", "1")]),
            Some(6.0)
        );
        let ttft = snap
            .sketch(TTFT_MS, &[("replica", "0"), ("tenant", "a")])
            .unwrap();
        assert_eq!(ttft.count, 1);
        assert_eq!(snap.counter(REQUESTS_COMPLETED_TOTAL, r), Some(1));
        assert_eq!(snap.counter(TOKENS_DECODED_TOTAL, r), Some(40));
        // One flight event per tick, valid JSON, dumpable.
        assert_eq!(rec.buffered(), 2);
        let dump = rec.trigger("test").unwrap();
        for line in dump.lines() {
            Json::parse(line).unwrap();
        }
    }

    #[test]
    fn sampler_detects_preemption_storms() {
        let live = LiveMetrics::new();
        let rec = FlightRecorder::new(8).with_storm_threshold(4);
        let mut sampler =
            WorkerSampler::new(live, rec.clone(), 1);
        let mut stats = PoolStats::default();
        sampler.sample_tick(0, 0, &stats, &[]);
        stats.preemptions = 6; // +6 in one tick ≥ threshold
        sampler.sample_tick(1, 0, &stats, &[]);
        let dumps = rec.dumps();
        assert_eq!(dumps.len(), 1);
        // The latch (and the dump reason) is keyed by this sampler's
        // replica label.
        assert_eq!(dumps[0].reason, "preemption-storm@1");
    }

    #[test]
    fn disabled_sampler_publishes_nothing() {
        let mut sampler = WorkerSampler::disabled(0);
        let stats = PoolStats { preemptions: 100,
                                ..PoolStats::default() };
        sampler.sample_tick(0, 9, &stats, &[shard_view(0, 1, 1, 1)]);
        sampler.observe_ttft_ms("a", 1.0);
        sampler.note_completion(5);
        sampler.note_progress(3, 30);
        // Series handles register eagerly (so an enable flip works
        // mid-run) but every value stays untouched.
        let snap = sampler.live().snapshot();
        assert!(snap.counters.iter().all(|(_, v)| *v == 0));
        assert!(snap.gauges.iter().all(|(_, v)| *v == 0.0));
        assert!(snap.sketches.iter().all(|(_, s)| s.is_empty()));
        assert!(sampler.recorder().dumps().is_empty());
        assert_eq!(sampler.recorder().buffered(), 0);
    }
}
