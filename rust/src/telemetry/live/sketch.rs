//! Mergeable streaming quantile sketch (DDSketch-flavoured).
//!
//! The live metrics plane must answer "what is TTFT p99 *right now*"
//! at any scheduler tick without retaining per-request samples the way
//! `substrate::metrics::Histogram` does. This sketch keeps
//! log-spaced bucket counts: bucket `i` covers `(γ^(i-1), γ^i]` with
//! `γ = (1+α)/(1-α)`, so the midpoint estimate `2γ^i/(γ+1)` is within
//! relative error `α` of any sample in the bucket — and therefore any
//! quantile estimate is within `α` (relative) of the exact
//! same-rank order statistic. Bucket counts are plain atomics:
//! recording is a handful of relaxed `fetch_add`s (plus CAS loops for
//! the f64 sum/min/max), so many worker threads can observe into one
//! sketch without a lock, and two sketches (or snapshots) with the
//! same `α` merge by summing counts — the property the fleet
//! dashboard uses to collapse per-`(replica, tenant)` series into
//! per-replica and per-tenant rows.

use std::sync::atomic::{AtomicU64, Ordering};

/// Default relative-error bound (1%): p99 TTFT of 250 ms is reported
/// within ±2.5 ms.
pub const DEFAULT_ALPHA: f64 = 0.01;

/// Values at or below this magnitude land in the dedicated zero
/// bucket (quantiles there report the exact tracked minimum).
const MIN_TRACKED: f64 = 1e-6;

/// Log-spaced bucket count. With α = 1% this spans `MIN_TRACKED` up
/// to ~1e11, far beyond any latency in seconds or milliseconds.
const NUM_BUCKETS: usize = 2048;

fn atomic_f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed,
                                         Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

fn atomic_f64_min(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        if f64::from_bits(cur) <= v {
            return;
        }
        match cell.compare_exchange_weak(cur, v.to_bits(),
                                         Ordering::Relaxed,
                                         Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

fn atomic_f64_max(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        if f64::from_bits(cur) >= v {
            return;
        }
        match cell.compare_exchange_weak(cur, v.to_bits(),
                                         Ordering::Relaxed,
                                         Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Thread-safe streaming quantile sketch. Shared via `Arc` (handles
/// cached by samplers/workers record concurrently); snapshot with
/// [`QuantileSketch::snapshot`] for consistent reads and merging.
#[derive(Debug)]
pub struct QuantileSketch {
    gamma: f64,
    inv_ln_gamma: f64,
    /// Bucket index (in γ-space) mapped to `counts[0]`.
    offset: i64,
    counts: Vec<AtomicU64>,
    /// Samples with magnitude ≤ `MIN_TRACKED` (incl. zeros/negatives).
    zero: AtomicU64,
    total: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl QuantileSketch {
    /// A sketch with the default 1% relative-error bound.
    pub fn new() -> Self {
        Self::with_alpha(DEFAULT_ALPHA)
    }

    /// A sketch with relative-error bound `alpha` in (0, 1).
    pub fn with_alpha(alpha: f64) -> Self {
        let alpha = alpha.clamp(1e-4, 0.5);
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        let inv_ln_gamma = 1.0 / gamma.ln();
        let offset = (MIN_TRACKED.ln() * inv_ln_gamma).ceil() as i64;
        let mut counts = Vec::with_capacity(NUM_BUCKETS);
        for _ in 0..NUM_BUCKETS {
            counts.push(AtomicU64::new(0));
        }
        QuantileSketch {
            gamma,
            inv_ln_gamma,
            offset,
            counts,
            zero: AtomicU64::new(0),
            total: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    /// The relative-error bound this sketch was built with.
    pub fn alpha(&self) -> f64 {
        (self.gamma - 1.0) / (self.gamma + 1.0)
    }

    /// Record one sample. Lock-free: relaxed atomics only.
    pub fn record(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.total.fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&self.sum_bits, v);
        atomic_f64_min(&self.min_bits, v);
        atomic_f64_max(&self.max_bits, v);
        if v <= MIN_TRACKED {
            self.zero.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let idx = (v.ln() * self.inv_ln_gamma).ceil() as i64 - self.offset;
        let idx = idx.clamp(0, NUM_BUCKETS as i64 - 1) as usize;
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 { 0.0 } else { self.sum() / n as f64 }
    }

    /// Exact smallest recorded sample (0.0 when empty, matching
    /// `Histogram::min`).
    pub fn min(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        f64::from_bits(self.min_bits.load(Ordering::Relaxed))
    }

    /// Exact largest recorded sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Estimate the `p`-th percentile (`p` in [0, 100]) within `α`
    /// relative error of the exact same-rank order statistic (the
    /// rank convention matches `Histogram::percentile`).
    pub fn percentile(&self, p: f64) -> f64 {
        self.snapshot().percentile(p)
    }

    /// Non-atomic copy for consistent reads, merging, and rendering.
    pub fn snapshot(&self) -> SketchSnapshot {
        SketchSnapshot {
            gamma: self.gamma,
            offset: self.offset,
            counts: self
                .counts
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            zero: self.zero.load(Ordering::Relaxed),
            count: self.total.load(Ordering::Relaxed),
            sum: self.sum(),
            min: f64::from_bits(self.min_bits.load(Ordering::Relaxed)),
            max: f64::from_bits(self.max_bits.load(Ordering::Relaxed)),
        }
    }
}

impl Default for QuantileSketch {
    fn default() -> Self {
        QuantileSketch::new()
    }
}

/// A point-in-time copy of a sketch: mergeable (same `α`) and
/// queryable without touching the live atomics.
#[derive(Debug, Clone)]
pub struct SketchSnapshot {
    gamma: f64,
    offset: i64,
    counts: Vec<u64>,
    zero: u64,
    pub count: u64,
    pub sum: f64,
    min: f64,
    max: f64,
}

impl SketchSnapshot {
    /// An empty snapshot with the default `α` (merge identity).
    pub fn empty() -> Self {
        QuantileSketch::new().snapshot()
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Estimate the `p`-th percentile (`p` in [0, 100]); see
    /// [`QuantileSketch::percentile`].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        // Same rank convention as `Histogram::percentile`: the index
        // into the sorted sample vector the exact path would read.
        let rank =
            ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut cum = self.zero;
        if rank < cum {
            return self.min;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if rank < cum {
                // Midpoint estimate of bucket (γ^(i-1), γ^i].
                let est = 2.0
                    * self.gamma.powi((i as i64 + self.offset) as i32)
                    / (self.gamma + 1.0);
                return est.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Merge `other` into `self` by summing bucket counts. Both sides
    /// must share `α` (the registry only ever builds default-`α`
    /// sketches); a shape mismatch merges scalars only.
    pub fn merge(&mut self, other: &SketchSnapshot) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        if (self.gamma - other.gamma).abs() < 1e-12
            && self.offset == other.offset
            && self.counts.len() == other.counts.len()
        {
            for (a, b) in self.counts.iter_mut().zip(&other.counts) {
                *a += b;
            }
            self.zero += other.zero;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// `n=.. mean=.. p50=.. p99=..` one-liner for tables.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3} p50={:.3} p99={:.3}",
            self.count,
            self.mean(),
            self.percentile(50.0),
            self.percentile(99.0)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::metrics::Histogram;
    use crate::substrate::rng::Rng;

    #[test]
    fn empty_sketch_is_safe() {
        let s = QuantileSketch::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.mean(), 0.0);
        assert!(!s.snapshot().summary().contains("inf"));
    }

    #[test]
    fn single_sample_is_exact_at_every_percentile() {
        let s = QuantileSketch::new();
        s.record(42.0);
        for p in [0.0, 50.0, 99.0, 100.0] {
            let got = s.percentile(p);
            assert!((got - 42.0).abs() <= 42.0 * s.alpha(), "p{p}: {got}");
        }
        // min/max are tracked exactly, not bucket estimates.
        assert_eq!(s.min(), 42.0);
        assert_eq!(s.max(), 42.0);
    }

    #[test]
    fn zero_and_negative_samples_hit_the_zero_bucket() {
        let s = QuantileSketch::new();
        s.record(0.0);
        s.record(-3.0);
        s.record(10.0);
        assert_eq!(s.count(), 3);
        assert_eq!(s.min(), -3.0);
        // p0 and p50 ranks fall inside the zero bucket → exact min.
        assert_eq!(s.percentile(0.0), -3.0);
        assert_eq!(s.percentile(50.0), -3.0);
        assert!((s.percentile(100.0) - 10.0).abs() <= 10.0 * s.alpha());
    }

    #[test]
    fn nonfinite_samples_are_ignored() {
        let s = QuantileSketch::new();
        s.record(f64::NAN);
        s.record(f64::INFINITY);
        assert_eq!(s.count(), 0);
        s.record(5.0);
        assert_eq!(s.count(), 1);
    }

    /// Satellite acceptance: sketch quantiles track an exact
    /// `Histogram` within the advertised relative-error bound, on a
    /// heavy-tailed sample set spanning several orders of magnitude.
    #[test]
    fn quantiles_match_exact_histogram_within_alpha() {
        let mut rng = Rng::new(17);
        let sketch = QuantileSketch::new();
        let mut exact = Histogram::new();
        for _ in 0..5000 {
            // Log-uniform over [0.1, 10_000) — heavier tail than any
            // latency distribution the replays produce.
            let v = 10f64.powf(rng.f64() * 5.0 - 1.0);
            sketch.record(v);
            exact.record(v);
        }
        let alpha = sketch.alpha();
        for p in [1.0, 5.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9] {
            let e = exact.percentile(p);
            let s = sketch.percentile(p);
            assert!(
                (s - e).abs() <= alpha * e + 1e-9,
                "p{p}: sketch {s} vs exact {e} (bound {})",
                alpha * e
            );
        }
        assert_eq!(sketch.min(), exact.min());
        assert_eq!(sketch.max(), exact.max());
        assert!((sketch.mean() - exact.mean()).abs()
                    <= 1e-9 * exact.mean().abs() + 1e-9);
    }

    /// Merging two sketches must answer like one sketch fed both
    /// streams — the property fleet-row aggregation depends on.
    #[test]
    fn merged_snapshots_equal_single_sketch_over_union() {
        let mut rng = Rng::new(23);
        let a = QuantileSketch::new();
        let b = QuantileSketch::new();
        let union = QuantileSketch::new();
        for i in 0..2000 {
            let v = 1.0 + rng.f64() * 500.0;
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            union.record(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged.count, union.count());
        assert!((merged.sum - union.sum()).abs() < 1e-6);
        for p in [10.0, 50.0, 90.0, 99.0] {
            let m = merged.percentile(p);
            let u = union.percentile(p);
            assert!(
                (m - u).abs() <= 1e-9 + u * 1e-12,
                "p{p}: merged {m} vs union {u}"
            );
        }
        // Merge identity: empty + x == x.
        let mut e = SketchSnapshot::empty();
        e.merge(&union.snapshot());
        assert_eq!(e.count, union.count());
        assert_eq!(e.percentile(50.0), union.percentile(50.0));
    }

    #[test]
    fn concurrent_records_lose_nothing() {
        use std::sync::Arc;
        let s = Arc::new(QuantileSketch::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    s.record((t * 1000 + i) as f64 + 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.count(), 4000);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4000.0);
        let expected_sum = (1..=4000u64).sum::<u64>() as f64;
        assert!((s.sum() - expected_sum).abs() < 1e-6,
                "CAS adds must not drop updates");
    }
}
