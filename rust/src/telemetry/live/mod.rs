//! Live (mid-run) observability plane.
//!
//! The tracer/aggregate/attribution stack is post-hoc: it retains
//! every span and folds them after the run. This module is the
//! always-on counterpart the ROADMAP autoscaler and SLO-aware tick
//! planning consume *during* the run:
//!
//! * [`registry`] — [`LiveMetrics`]: labeled atomic counters/gauges
//!   plus streaming quantile sketches, snapshot-consistent, with the
//!   tracer's one-relaxed-load disabled mode.
//! * [`sketch`] — [`QuantileSketch`]: mergeable DDSketch-style
//!   quantiles, so TTFT/TBT p50/p99 are queryable at any tick without
//!   retaining samples.
//! * [`sampler`] — [`WorkerSampler`]: the per-tick publication point
//!   (queue depth, per-shard pages, prefix hit rate, capacity waits,
//!   spills, preemptions) and [`OnlineAttribution`], the incremental
//!   idle-gap fold.
//! * [`recorder`] — [`FlightRecorder`]: bounded ring of structured
//!   JSONL events, dumped on crash, preemption storm, or SIGTERM.
//! * [`prometheus`] — text exposition of a registry snapshot
//!   (`--metrics-out`).

pub mod prometheus;
pub mod recorder;
pub mod registry;
pub mod sampler;
pub mod sketch;

pub use recorder::{install_sigterm_hook, FlightRecorder};
pub use registry::{Counter, Gauge, LiveMetrics, MetricsSnapshot,
                   Series};
pub use sampler::{OnlineAttribution, WorkerSampler};
pub use sketch::{QuantileSketch, SketchSnapshot};
