//! Bounded ring-buffer flight recorder.
//!
//! Keeps the last N ticks of structured JSONL events in memory and
//! dumps them — newest context preserved, oldest evicted — when
//! something goes wrong: a replica crash (the routing replay's
//! `KillSpec` injection, or a worker exiting with an error), a
//! preemption storm (more than `storm_threshold` preemptions observed
//! in one tick), or SIGTERM. The dump is one JSONL document: a header
//! line naming the trigger, then the buffered event lines in order.
//! Disabled mode follows the tracer contract: one relaxed atomic load
//! per would-be event.

use std::collections::{BTreeSet, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::substrate::json::Json;

/// Default ring capacity (ticks of context kept for a dump).
pub const DEFAULT_CAPACITY: usize = 256;

/// Default preemption-storm trigger: preemption delta in one tick at
/// or above this dumps the ring.
pub const DEFAULT_STORM_THRESHOLD: u64 = 8;

/// One completed dump (kept in memory for tests/reports even when a
/// dump file is also written).
#[derive(Debug, Clone)]
pub struct Dump {
    pub reason: String,
    pub jsonl: String,
}

#[derive(Debug, Default)]
struct RecState {
    buf: VecDeque<String>,
    seq: u64,
    dumps: Vec<Dump>,
    dump_path: Option<PathBuf>,
    /// Replicas whose storm latch is currently set (per-replica: a
    /// healthy replica must never dump — or re-arm — because a sick
    /// one is storming).
    storm_fired: BTreeSet<String>,
    sigterm_fired: bool,
}

#[derive(Debug)]
struct RecCore {
    enabled: AtomicBool,
    cap: usize,
    storm_threshold: u64,
    state: Mutex<RecState>,
}

/// Cloneable flight-recorder handle (`Send + Sync`).
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    core: Arc<RecCore>,
}

impl FlightRecorder {
    /// An enabled recorder holding the last `cap` events.
    pub fn new(cap: usize) -> Self {
        Self::build(cap.max(1), DEFAULT_STORM_THRESHOLD, true)
    }

    /// A disabled recorder: every record is one relaxed atomic load.
    pub fn disabled() -> Self {
        Self::build(1, DEFAULT_STORM_THRESHOLD, false)
    }

    fn build(cap: usize, storm_threshold: u64, on: bool) -> Self {
        FlightRecorder {
            core: Arc::new(RecCore {
                enabled: AtomicBool::new(on),
                cap,
                storm_threshold,
                state: Mutex::new(RecState::default()),
            }),
        }
    }

    /// Override the preemption-storm trigger threshold (0 disables).
    pub fn with_storm_threshold(self, threshold: u64) -> Self {
        let cap = self.core.cap;
        let on = self.is_enabled();
        Self::build(cap, threshold, on)
    }

    pub fn is_enabled(&self) -> bool {
        self.core.enabled.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.core.cap
    }

    /// Where `trigger` appends its dump (unset = in-memory only).
    pub fn set_dump_path(&self, path: Option<PathBuf>) {
        self.lock().dump_path = path;
    }

    fn lock(&self) -> MutexGuard<'_, RecState> {
        self.core
            .state
            .lock()
            .unwrap_or_else(|poison| poison.into_inner())
    }

    /// Record one structured event (an object; other Json values are
    /// wrapped). A monotonically increasing `seq` field is prepended
    /// so dump readers can see exactly how much history was evicted.
    pub fn record(&self, event: Json) {
        if !self.is_enabled() {
            return;
        }
        let mut st = self.lock();
        st.seq += 1;
        let seq = st.seq;
        let stamped = match event {
            Json::Obj(mut fields) => {
                fields.insert(0, ("seq".to_string(),
                                  Json::Num(seq as f64)));
                Json::Obj(fields)
            }
            other => Json::from_obj(vec![
                ("seq".into(), Json::Num(seq as f64)),
                ("event".into(), other),
            ]),
        };
        st.buf.push_back(stamped.to_string());
        while st.buf.len() > self.core.cap {
            st.buf.pop_front();
        }
    }

    /// Dump the ring as one JSONL document (header line + events in
    /// order), append it to the dump path when set, and retain it in
    /// memory. Returns `None` when disabled.
    pub fn trigger(&self, reason: &str) -> Option<String> {
        if !self.is_enabled() {
            return None;
        }
        let mut st = self.lock();
        let header = Json::from_obj(vec![
            ("flight_dump".into(), Json::Str(reason.to_string())),
            ("events".into(), Json::Num(st.buf.len() as f64)),
            ("last_seq".into(), Json::Num(st.seq as f64)),
        ]);
        let mut out = String::new();
        out.push_str(&header.to_string());
        out.push('\n');
        for line in &st.buf {
            out.push_str(line);
            out.push('\n');
        }
        if let Some(path) = &st.dump_path {
            use std::io::Write;
            let res = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .and_then(|mut f| f.write_all(out.as_bytes()));
            if let Err(e) = res {
                eprintln!(
                    "[mmserve] flight dump to {} failed: {e}",
                    path.display()
                );
            }
        }
        st.dumps.push(Dump {
            reason: reason.to_string(),
            jsonl: out.clone(),
        });
        Some(out)
    }

    /// Preemption delta for one tick on `replica`; at/above the storm
    /// threshold the ring dumps once (`preemption-storm@<replica>`),
    /// re-arming only after a calm tick *on the same replica* — a
    /// sustained storm produces one dump, not one per tick, and a
    /// healthy replica's calm ticks neither trigger nor re-arm a sick
    /// replica's latch.
    pub fn note_preemptions(&self, replica: &str, delta: u64) {
        if !self.is_enabled() || self.core.storm_threshold == 0 {
            return;
        }
        if delta == 0 {
            self.lock().storm_fired.remove(replica);
            return;
        }
        if delta >= self.core.storm_threshold {
            let newly =
                self.lock().storm_fired.insert(replica.to_string());
            if newly {
                self.trigger(&format!("preemption-storm@{replica}"));
            }
        }
    }

    /// Poll the process-level SIGTERM flag; first observation dumps
    /// the ring (`sigterm`). Call once per tick from any driver loop.
    pub fn poll_sigterm(&self) {
        if !self.is_enabled() || !sigterm_requested() {
            return;
        }
        let fired = {
            let mut st = self.lock();
            let was = st.sigterm_fired;
            st.sigterm_fired = true;
            was
        };
        if !fired {
            self.trigger("sigterm");
        }
    }

    /// All dumps taken so far (crash, storm, sigterm).
    pub fn dumps(&self) -> Vec<Dump> {
        self.lock().dumps.clone()
    }

    /// Events currently buffered (for tests/reports).
    pub fn buffered(&self) -> usize {
        self.lock().buf.len()
    }
}

// ---- SIGTERM hook ----------------------------------------------------------

static SIGTERM_SEEN: AtomicBool = AtomicBool::new(false);

/// Mark the process as terminating — the cooperative path the real
/// handler also takes, and the portable fallback for tests and
/// non-unix targets.
pub fn request_sigterm_dump() {
    SIGTERM_SEEN.store(true, Ordering::SeqCst);
}

/// Whether SIGTERM (or a cooperative request) has been observed.
pub fn sigterm_requested() -> bool {
    SIGTERM_SEEN.load(Ordering::SeqCst)
}

#[cfg(unix)]
mod sig {
    use super::SIGTERM_SEEN;
    use std::sync::atomic::Ordering;

    const SIGTERM: i32 = 15;

    extern "C" fn on_sigterm(_signum: i32) {
        // Only the async-signal-safe store; the dump happens on the
        // next `poll_sigterm` from a driver loop.
        SIGTERM_SEEN.store(true, Ordering::SeqCst);
    }

    unsafe extern "C" {
        fn signal(signum: i32,
                  handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_sigterm);
        }
    }
}

/// Install the process SIGTERM handler (idempotent; no-op off unix).
/// Driver loops then call [`FlightRecorder::poll_sigterm`] per tick.
pub fn install_sigterm_hook() {
    #[cfg(unix)]
    sig::install();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(tick: u64) -> Json {
        Json::from_obj(vec![
            ("tick".into(), Json::Num(tick as f64)),
            ("kind".into(), Json::Str("tick-sample".to_string())),
        ])
    }

    #[test]
    fn ring_keeps_last_n_and_dumps_valid_jsonl() {
        let rec = FlightRecorder::new(4);
        for t in 0..10 {
            rec.record(ev(t));
        }
        assert_eq!(rec.buffered(), 4);
        let dump = rec.trigger("replica-crash").unwrap();
        let lines: Vec<&str> =
            dump.lines().filter(|l| !l.is_empty()).collect();
        assert_eq!(lines.len(), 5, "header + 4 events");
        // Every line must be valid JSON (the acceptance criterion).
        for line in &lines {
            Json::parse(line).unwrap_or_else(|e| {
                panic!("invalid JSONL line {line:?}: {e}")
            });
        }
        let header = Json::parse(lines[0]).unwrap();
        assert_eq!(
            header.get("flight_dump").and_then(Json::as_str),
            Some("replica-crash")
        );
        assert_eq!(header.get("events").and_then(Json::as_f64),
                   Some(4.0));
        // Oldest events were evicted: first kept tick is 6, and its
        // seq shows how much history rolled off.
        let first = Json::parse(lines[1]).unwrap();
        assert_eq!(first.get("tick").and_then(Json::as_f64), Some(6.0));
        assert_eq!(first.get("seq").and_then(Json::as_f64), Some(7.0));
        let dumps = rec.dumps();
        assert_eq!(dumps.len(), 1);
        assert_eq!(dumps[0].reason, "replica-crash");
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let rec = FlightRecorder::disabled();
        rec.record(ev(1));
        assert_eq!(rec.buffered(), 0);
        assert!(rec.trigger("x").is_none());
        rec.note_preemptions("0", 1_000);
        assert!(rec.dumps().is_empty());
    }

    #[test]
    fn storm_threshold_dumps_once_until_calm() {
        let rec = FlightRecorder::new(8).with_storm_threshold(4);
        rec.record(ev(0));
        rec.note_preemptions("0", 2); // below threshold
        assert!(rec.dumps().is_empty());
        rec.note_preemptions("0", 5); // storm
        rec.note_preemptions("0", 9); // still storming: no 2nd dump
        assert_eq!(rec.dumps().len(), 1);
        assert_eq!(rec.dumps()[0].reason, "preemption-storm@0");
        rec.note_preemptions("0", 0); // calm re-arms
        rec.note_preemptions("0", 4);
        assert_eq!(rec.dumps().len(), 2);
    }

    /// Regression: the storm latch is per-replica. A healthy replica
    /// must not dump (and its calm ticks must not re-arm the latch)
    /// because a sick replica is storming.
    #[test]
    fn storm_latch_is_per_replica() {
        let rec = FlightRecorder::new(8).with_storm_threshold(4);
        rec.note_preemptions("1", 6); // replica 1 storms
        assert_eq!(rec.dumps().len(), 1);
        assert_eq!(rec.dumps()[0].reason, "preemption-storm@1");
        // Healthy replica 0 ticks calmly: no dump, and replica 1's
        // latch must stay set.
        rec.note_preemptions("0", 0);
        rec.note_preemptions("1", 9);
        assert_eq!(rec.dumps().len(), 1, "latch survives other \
                                          replicas' calm ticks");
        rec.note_preemptions("0", 1); // below threshold: still quiet
        assert_eq!(rec.dumps().len(), 1);
        // An independent storm on replica 0 is its own dump.
        rec.note_preemptions("0", 5);
        assert_eq!(rec.dumps().len(), 2);
        assert_eq!(rec.dumps()[1].reason, "preemption-storm@0");
    }

    #[test]
    fn dump_file_append_and_nonobject_events() {
        let dir = std::env::temp_dir()
            .join("mmserve_flight_test")
            .join(format!("pid{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flight.jsonl");
        let _ = std::fs::remove_file(&path);
        let rec = FlightRecorder::new(8);
        rec.set_dump_path(Some(path.clone()));
        rec.record(Json::Str("bare".to_string()));
        rec.trigger("a");
        rec.trigger("b");
        let body = std::fs::read_to_string(&path).unwrap();
        // Two appended dumps: 2 headers + 2 copies of the one event.
        assert_eq!(body.lines().count(), 4);
        for line in body.lines() {
            Json::parse(line).unwrap();
        }
        let wrapped = Json::parse(body.lines().nth(1).unwrap()).unwrap();
        assert_eq!(wrapped.get("event").and_then(Json::as_str),
                   Some("bare"));
        assert_eq!(wrapped.get("seq").and_then(Json::as_f64), Some(1.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cooperative_sigterm_dump_fires_once() {
        // The real handler only sets the same flag this helper sets;
        // exercising the flag path covers everything but the signal
        // delivery itself.
        install_sigterm_hook();
        let rec = FlightRecorder::new(4);
        rec.record(ev(1));
        rec.poll_sigterm();
        assert!(rec.dumps().is_empty(), "no dump before the flag");
        request_sigterm_dump();
        assert!(sigterm_requested());
        rec.poll_sigterm();
        rec.poll_sigterm();
        assert_eq!(rec.dumps().len(), 1, "one dump per recorder");
        assert_eq!(rec.dumps()[0].reason, "sigterm");
    }
}
