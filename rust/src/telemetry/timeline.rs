//! Step timeline: per-scheduler-tick records folded from a trace.
//!
//! The continuous batcher tags every span with the current tick (via
//! `WorkerTracer::set_tick`); this module groups those spans back into
//! one record per tick — when the tick started/ended, how much of it
//! was prefill / decode-execute / sampling / host gap — which is the
//! per-step timeline the paper's Figure-3 methodology is built on.

use std::collections::HashMap;

use crate::substrate::metrics::OpTimes;
use crate::substrate::table::Table;

use super::tracer::{union_len, Cat, Trace};

/// One scheduler tick (or one bs=1 decode step).
#[derive(Debug, Clone)]
pub struct TickRecord {
    /// Worker the tick ran on (ticks are per-worker, never reused).
    pub tid: u64,
    pub index: u64,
    pub t0: f64,
    pub t1: f64,
    /// Per-category time within the tick (keys are `Cat::as_str()`).
    pub phases: OpTimes,
    /// Distinct requests touched during the tick.
    pub requests: usize,
}

impl TickRecord {
    pub fn dur(&self) -> f64 {
        self.t1 - self.t0
    }
}

/// The tick-ordered timeline of a serving run.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub ticks: Vec<TickRecord>,
}

impl Timeline {
    /// Fold tick-tagged spans of a trace into per-tick records, keyed
    /// by `(worker, tick)` — tick indices are per-worker monotonic
    /// (`WorkerTracer::next_tick`), so the key is unique per step.
    /// Phase spans (`Prefill`/`Decode`/`Other`) wrap the finer-grained
    /// work and are not added to the per-category accumulators (they
    /// would double-count), but they do extend the tick bounds.
    pub fn from_trace(tr: &Trace) -> Timeline {
        let mut recs: HashMap<(u64, u64), (TickRecord, Vec<u64>)> =
            HashMap::new();
        for s in &tr.spans {
            let Some(tick) = s.tick else { continue };
            let (rec, reqs) = recs
                .entry((s.tid, tick))
                .or_insert_with(|| (TickRecord {
                    tid: s.tid,
                    index: tick,
                    t0: s.t0,
                    t1: s.t1,
                    phases: OpTimes::new(),
                    requests: 0,
                }, Vec::new()));
            rec.t0 = rec.t0.min(s.t0);
            rec.t1 = rec.t1.max(s.t1);
            if !matches!(s.cat, Cat::Prefill | Cat::Decode
                                | Cat::PrefillStall | Cat::Other) {
                rec.phases.add(s.cat.as_str(), s.dur());
            }
            if let Some(req) = s.req {
                reqs.push(req);
            }
        }
        let mut ticks: Vec<TickRecord> = recs
            .into_values()
            .map(|(mut rec, mut reqs)| {
                reqs.sort_unstable();
                reqs.dedup();
                rec.requests = reqs.len();
                rec
            })
            .collect();
        ticks.sort_by(|a, b| {
            a.t0.total_cmp(&b.t0)
                .then_with(|| (a.tid, a.index).cmp(&(b.tid, b.index)))
        });
        Timeline { ticks }
    }

    pub fn len(&self) -> usize {
        self.ticks.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ticks.is_empty()
    }

    /// Mean tick duration in seconds (0 when empty).
    pub fn mean_tick_secs(&self) -> f64 {
        if self.ticks.is_empty() {
            return 0.0;
        }
        self.ticks.iter().map(|t| t.dur()).sum::<f64>()
            / self.ticks.len() as f64
    }

    /// Fraction of total tick time spent in device execution.
    pub fn execute_fraction(&self) -> f64 {
        let total: f64 = self.ticks.iter().map(|t| t.dur()).sum();
        if total == 0.0 {
            return 0.0;
        }
        let exec: f64 = self
            .ticks
            .iter()
            .map(|t| t.phases.get(Cat::Execute.as_str()))
            .sum();
        exec / total
    }

    /// Render the timeline as a per-tick table (first `max_rows` ticks).
    pub fn render(&self, max_rows: usize) -> String {
        let mut table = Table::new(&[
            "tick", "start(ms)", "dur(ms)", "exec(ms)", "sample(ms)",
            "sched(ms)", "sync(ms)", "reqs",
        ]);
        for t in self.ticks.iter().take(max_rows) {
            let sync = t.phases.get(Cat::Upload.as_str())
                + t.phases.get(Cat::Download.as_str());
            table.row(&[
                t.index.to_string(),
                format!("{:.3}", t.t0 * 1e3),
                format!("{:.3}", t.dur() * 1e3),
                format!("{:.3}", t.phases.get(Cat::Execute.as_str()) * 1e3),
                format!("{:.3}", t.phases.get(Cat::Sample.as_str()) * 1e3),
                format!("{:.3}", t.phases.get(Cat::Schedule.as_str()) * 1e3),
                format!("{:.3}", sync * 1e3),
                t.requests.to_string(),
            ]);
        }
        let mut out = table.render();
        if self.ticks.len() > max_rows {
            out.push_str(&format!("  … {} more ticks\n",
                                  self.ticks.len() - max_rows));
        }
        out
    }

    /// Union of tick windows (the active portion of the run).
    pub fn active_secs(&self) -> f64 {
        union_len(self.ticks.iter().map(|t| (t.t0, t.t1)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::tracer::Span;
    use super::*;

    fn sp(cat: Cat, t0: f64, t1: f64, tick: Option<u64>, req: Option<u64>)
          -> Span {
        Span { name: cat.as_str().to_string(), cat, t0, t1, tid: 1, req,
               tick }
    }

    #[test]
    fn folds_ticks_in_order() {
        let tr = Trace {
            spans: vec![
                sp(Cat::Execute, 1.0, 1.5, Some(1), Some(10)),
                sp(Cat::Sample, 1.5, 1.6, Some(1), Some(10)),
                sp(Cat::Execute, 0.0, 0.5, Some(0), Some(10)),
                sp(Cat::Schedule, 0.5, 0.6, Some(0), None),
                sp(Cat::Other, 2.0, 2.1, None, None),
            ],
            workers: vec![(1, "w".into())],
        };
        let tl = Timeline::from_trace(&tr);
        assert_eq!(tl.len(), 2);
        assert_eq!(tl.ticks[0].index, 0);
        assert!((tl.ticks[0].dur() - 0.6).abs() < 1e-12);
        assert!((tl.ticks[0].phases.get("Execute") - 0.5).abs() < 1e-12);
        assert_eq!(tl.ticks[0].requests, 1);
        assert_eq!(tl.ticks[1].index, 1);
        assert!((tl.mean_tick_secs() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn phase_spans_extend_bounds_but_do_not_double_count() {
        let tr = Trace {
            spans: vec![
                sp(Cat::Decode, 0.0, 1.0, Some(0), None),
                sp(Cat::Execute, 0.2, 0.7, Some(0), None),
            ],
            workers: vec![],
        };
        let tl = Timeline::from_trace(&tr);
        assert_eq!(tl.len(), 1);
        assert!((tl.ticks[0].dur() - 1.0).abs() < 1e-12);
        assert!((tl.ticks[0].phases.total() - 0.5).abs() < 1e-12);
        assert!((tl.execute_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn same_tick_index_on_different_workers_stays_separate() {
        let mut a = sp(Cat::Execute, 0.0, 0.5, Some(0), Some(1));
        let mut b = sp(Cat::Execute, 0.1, 0.6, Some(0), Some(2));
        a.tid = 1;
        b.tid = 2;
        let tl = Timeline::from_trace(&Trace {
            spans: vec![a, b],
            workers: vec![(1, "w1".into()), (2, "w2".into())],
        });
        assert_eq!(tl.len(), 2, "tick 0 of two workers must not merge");
        assert!((tl.ticks[0].dur() - 0.5).abs() < 1e-12);
        assert_eq!(tl.ticks[0].tid, 1);
        assert_eq!(tl.ticks[1].tid, 2);
    }

    #[test]
    fn render_caps_rows() {
        let spans: Vec<Span> = (0..10)
            .map(|i| sp(Cat::Execute, i as f64, i as f64 + 0.5,
                        Some(i as u64), None))
            .collect();
        let tl = Timeline::from_trace(&Trace { spans, workers: vec![] });
        let s = tl.render(3);
        assert!(s.contains("… 7 more ticks"));
    }
}
