//! Chrome-trace exporter: `about://tracing` / Perfetto-compatible JSON.
//!
//! Emits the Trace Event Format's "X" (complete) events with
//! microsecond timestamps plus "M" metadata events naming each worker
//! thread, via `substrate::json` (no serde in this crate).

use std::path::Path;

use anyhow::{Context, Result};

use crate::substrate::json::Json;

use super::tracer::Trace;

/// Build the Chrome-trace JSON document for a trace.
pub fn to_json(tr: &Trace) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(
        tr.spans.len() + tr.workers.len(),
    );
    for (tid, name) in &tr.workers {
        events.push(Json::from_obj(vec![
            ("name".into(), Json::Str("thread_name".into())),
            ("ph".into(), Json::Str("M".into())),
            ("pid".into(), Json::Num(0.0)),
            ("tid".into(), Json::Num(*tid as f64)),
            ("args".into(), Json::from_obj(vec![
                ("name".into(), Json::Str(name.clone())),
            ])),
        ]));
    }
    for s in &tr.spans {
        let mut args = Vec::new();
        if let Some(r) = s.req {
            args.push(("req".into(), Json::Num(r as f64)));
        }
        if let Some(t) = s.tick {
            args.push(("tick".into(), Json::Num(t as f64)));
        }
        events.push(Json::from_obj(vec![
            ("name".into(), Json::Str(s.name.clone())),
            ("cat".into(), Json::Str(s.cat.as_str().into())),
            ("ph".into(), Json::Str("X".into())),
            ("ts".into(), Json::Num(s.t0 * 1e6)),
            ("dur".into(), Json::Num(s.dur() * 1e6)),
            ("pid".into(), Json::Num(0.0)),
            ("tid".into(), Json::Num(s.tid as f64)),
            ("args".into(), Json::from_obj(args)),
        ]));
    }
    Json::from_obj(vec![
        ("traceEvents".into(), Json::Arr(events)),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
    ])
}

/// Serialize and write `trace.json` for `chrome://tracing` / Perfetto.
pub fn write(path: &Path, tr: &Trace) -> Result<()> {
    std::fs::write(path, to_json(tr).to_string())
        .with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::super::tracer::{Cat, Span};
    use super::*;

    fn sample_trace() -> Trace {
        Trace {
            spans: vec![
                Span {
                    name: "decode_b4".into(),
                    cat: Cat::Execute,
                    t0: 0.001,
                    t1: 0.003,
                    tid: 1,
                    req: Some(42),
                    tick: Some(7),
                },
                Span {
                    name: "sample".into(),
                    cat: Cat::Sample,
                    t0: 0.003,
                    t1: 0.004,
                    tid: 1,
                    req: None,
                    tick: None,
                },
            ],
            workers: vec![(1, "Llama".into())],
        }
    }

    #[test]
    fn emits_valid_trace_event_json() {
        let j = to_json(&sample_trace());
        // must round-trip through the JSON parser
        let parsed = Json::parse(&j.to_string()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3); // 1 metadata + 2 spans
        let meta = &events[0];
        assert_eq!(meta.get("ph").unwrap().as_str(), Some("M"));
        assert_eq!(
            meta.get("args").unwrap().get("name").unwrap().as_str(),
            Some("Llama")
        );
        let e = &events[1];
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("cat").unwrap().as_str(), Some("Execute"));
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        let dur = e.get("dur").unwrap().as_f64().unwrap();
        assert!((ts - 1000.0).abs() < 1e-3, "ts {ts}");
        assert!((dur - 2000.0).abs() < 1e-3, "dur {dur}");
        assert_eq!(e.get("args").unwrap().get("req").unwrap().as_i64(),
                   Some(42));
        assert_eq!(e.get("args").unwrap().get("tick").unwrap().as_i64(),
                   Some(7));
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir();
        let path = dir.join("mmserve_chrome_trace_test.json");
        write(&path, &sample_trace()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&body).unwrap();
        assert!(parsed.get("traceEvents").is_some());
        let _ = std::fs::remove_file(&path);
    }
}
