//! GPU device specifications (public datasheet numbers — nothing fitted).

/// Device parameters used by the roofline cost model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Dense fp32 (CUDA-core) peak, FLOP/s.
    pub peak_f32: f64,
    /// TF32/bf16 tensor-core peak used for GEMMs, FLOP/s.
    pub peak_tensor: f64,
    /// Int8 tensor peak, OP/s.
    pub peak_int8: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_capacity: f64,
    /// CPU-side cost per kernel in the eager regime, seconds — Python
    /// interpreter + framework dispatcher + CUDA launch (the paper's
    /// Obs #2: "GPU computations can be faster than the time it takes
    /// to execute the corresponding python code on CPU"). Calibrated to
    /// PyTorch-eager per-op costs (~25 µs), not the bare ~5 µs driver
    /// launch.
    pub launch_overhead: f64,
    /// Fixed overhead to replay one captured graph, seconds.
    pub graph_launch: f64,
    /// Achievable fraction of peak for well-shaped GEMMs.
    pub gemm_eff: f64,
    /// Achievable fraction of peak BW for streaming kernels.
    pub mem_eff: f64,
}

/// NVIDIA A100-SXM4-80GB (Ampere).
pub const A100: DeviceSpec = DeviceSpec {
    name: "A100",
    peak_f32: 19.5e12,
    peak_tensor: 156e12, // TF32 tensor core
    peak_int8: 624e12,
    hbm_bw: 2.039e12,
    hbm_capacity: 80e9,
    launch_overhead: 25e-6,
    graph_launch: 20e-6,
    gemm_eff: 0.75,
    mem_eff: 0.80,
};

/// NVIDIA H100-SXM5-80GB (Hopper): ≈3× peak FLOPS, ≈1.5–1.6× HBM BW
/// vs A100 (paper §4.5).
pub const H100: DeviceSpec = DeviceSpec {
    name: "H100",
    peak_f32: 67e12,
    peak_tensor: 495e12, // TF32 tensor core (dense)
    peak_int8: 1979e12,
    hbm_bw: 3.35e12,
    hbm_capacity: 80e9,
    launch_overhead: 25e-6, // host-bound Python/dispatch cost, unchanged
    graph_launch: 20e-6,
    gemm_eff: 0.75,
    mem_eff: 0.80,
};

impl DeviceSpec {
    pub fn by_name(name: &str) -> Option<&'static DeviceSpec> {
        match name.to_ascii_uppercase().as_str() {
            "A100" => Some(&A100),
            "H100" => Some(&H100),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_beats_a100_everywhere() {
        assert!(H100.peak_tensor > 2.5 * A100.peak_tensor);
        assert!(H100.hbm_bw > 1.4 * A100.hbm_bw);
        assert_eq!(A100.hbm_capacity, H100.hbm_capacity);
    }

    #[test]
    fn lookup() {
        assert_eq!(DeviceSpec::by_name("a100").unwrap().name, "A100");
        assert!(DeviceSpec::by_name("tpu").is_none());
    }
}
