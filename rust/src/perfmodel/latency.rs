//! Task-level latency model: compose operator walks into end-to-end
//! inference costs for each of the paper's nine tasks.

use crate::substrate::metrics::OpTimes;

use super::configs::{PaperDecoder, PaperHstu, PaperSeamless};
use super::device::DeviceSpec;
use super::levers::{cost_walk, Levers};
use super::ops::{self, OpWalk};

/// Paper-scale description of one inference sample.
#[derive(Debug, Clone, Copy)]
pub enum TaskSpec {
    /// Llama / Chameleon: prompt → `decode_steps` tokens.
    /// `decodes_per_step` = 2 for Chameleon T-I (contrastive).
    Decoder {
        cfg: &'static PaperDecoder,
        batch: usize,
        prompt_len: usize,
        decode_steps: usize,
        decodes_per_step: usize,
    },
    /// Seamless: encoder frames → beam text decode → optional speech
    /// tail.
    Seamless {
        cfg: &'static PaperSeamless,
        src_len: usize,
        text_steps: usize,
        speech_out: bool,
        /// Reorder fused (compile'd) vs baseline copy.
        reorder_fused: bool,
        speech_in: bool,
    },
    /// HSTU: one non-AR forward.
    Hstu { cfg: &'static PaperHstu, batch: usize, seq: usize },
}

/// Cost decomposition of one sample.
#[derive(Debug, Clone)]
pub struct TaskCost {
    pub prefill_wall: f64,
    pub decode_wall: f64,
    pub total: f64,
    pub prefill_times: OpTimes,
    pub decode_times: OpTimes,
    pub flops: f64,
    pub bytes: f64,
}

/// LayerSkip economics (paper §4.3): effective decode speedup given
/// acceptance rate, draft-cost ratio E/L and window K.
pub fn layerskip_speedup(cfg: &PaperDecoder, accept: f64) -> f64 {
    let c = cfg.early_exit_layer as f64 / cfg.n_layers as f64;
    let k = cfg.verify_window as f64;
    let tokens = 1.0 + accept * (k - 1.0);
    let cost = (k - 1.0) * c + 1.0;
    tokens / cost
}

/// Default LayerSkip acceptance rate (paper reports ~1.5–1.8× at
/// K=8, E/L=0.25 ⇒ acceptance ≈ 0.55 for code/caption workloads).
pub const LAYERSKIP_ACCEPT: f64 = 0.55;

/// Cost one sample under a lever configuration.
pub fn task_cost(spec: &TaskSpec, dev: &DeviceSpec, lv: &Levers) -> TaskCost {
    match *spec {
        TaskSpec::Decoder {
            cfg,
            batch,
            prompt_len,
            decode_steps,
            decodes_per_step,
        } => {
            let attn = lv.attn_kind();
            let lin = lv.linear_kind();
            let pre = ops::decoder_prefill(cfg, batch, prompt_len, attn, lin);
            let (pre_wall, pre_times) = cost_walk(&pre, dev, lv.compile);
            // decode at the average context length
            let mut dec_all = OpWalk::default();
            let steps = decode_steps.max(1);
            // sample context at 8 points to approximate the growth
            let samples = 8.min(steps);
            for i in 0..samples {
                let ctx = prompt_len + (i + 1) * steps / samples;
                let w = ops::decoder_decode_step(cfg, batch, ctx, attn, lin);
                dec_all.extend(w.repeat(steps / samples.max(1)));
            }
            let mut dec = OpWalk::default();
            for _ in 0..decodes_per_step {
                dec.extend(dec_all.clone());
            }
            let (mut dec_wall, dec_times) = cost_walk(&dec, dev, lv.compile);
            if lv.layerskip {
                dec_wall /= layerskip_speedup(cfg, LAYERSKIP_ACCEPT);
            }
            TaskCost {
                prefill_wall: pre_wall,
                decode_wall: dec_wall,
                total: pre_wall + dec_wall,
                flops: pre.total_flops() + dec.total_flops(),
                bytes: pre.total_bytes() + dec.total_bytes(),
                prefill_times: pre_times,
                decode_times: dec_times,
            }
        }
        TaskSpec::Seamless {
            cfg,
            src_len,
            text_steps,
            speech_out,
            reorder_fused,
            speech_in,
        } => {
            let attn = lv.attn_kind();
            let mut pre = OpWalk::default();
            if speech_in {
                pre.extend(ops::seamless_encoder(cfg, src_len, attn));
            } else {
                // text encoder ≈ ¼ the conformer cost per token
                let mut enc = ops::seamless_encoder(cfg, src_len, attn);
                for op in &mut enc.ops {
                    op.flops *= 0.25;
                    op.bytes *= 0.25;
                }
                pre.extend(enc);
            }
            let (pre_wall, pre_times) = cost_walk(&pre, dev, lv.compile);

            let mut dec = OpWalk::default();
            let steps = text_steps.max(1);
            for i in 0..steps {
                dec.extend(ops::seamless_dec_step(cfg, cfg.beam, i + 1,
                                                  src_len, attn));
                dec.extend(ops::seamless_kv_reorder(
                    cfg, cfg.beam, i + 1,
                    reorder_fused || lv.compile,
                ));
            }
            if speech_out {
                dec.extend(ops::seamless_t2u(cfg, steps));
                dec.extend(ops::seamless_vocoder(
                    cfg, steps * cfg.t2u_upsample));
            }
            let (dec_wall, dec_times) = cost_walk(&dec, dev, lv.compile);
            TaskCost {
                prefill_wall: pre_wall,
                decode_wall: dec_wall,
                total: pre_wall + dec_wall,
                flops: pre.total_flops() + dec.total_flops(),
                bytes: pre.total_bytes() + dec.total_bytes(),
                prefill_times: pre_times,
                decode_times: dec_times,
            }
        }
        TaskSpec::Hstu { cfg, batch, seq } => {
            let w = ops::hstu_forward(cfg, batch, seq, lv.sdpa);
            let (wall, times) = cost_walk(&w, dev, lv.compile);
            TaskCost {
                prefill_wall: 0.0,
                decode_wall: wall,
                total: wall,
                flops: w.total_flops(),
                bytes: w.total_bytes(),
                prefill_times: OpTimes::new(),
                decode_times: times,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::configs::{HSTU_14L, LLAMA_34B, SEAMLESS_M4T};
    use super::super::device::A100;
    use super::*;

    fn llama_tt() -> TaskSpec {
        TaskSpec::Decoder {
            cfg: &LLAMA_34B,
            batch: 1,
            prompt_len: 154,
            decode_steps: 538,
            decodes_per_step: 1,
        }
    }

    #[test]
    fn decode_dominates_autoregressive_latency() {
        // Obs #1: many decode steps ⇒ decode ≫ prefill.
        let c = task_cost(&llama_tt(), &A100, &Levers::baseline());
        assert!(c.decode_wall > 5.0 * c.prefill_wall);
    }

    #[test]
    fn levers_strictly_improve_decoder_latency() {
        let base = task_cost(&llama_tt(), &A100, &Levers::baseline()).total;
        let sdpa = task_cost(&llama_tt(), &A100, &Levers::sdpa()).total;
        let cmp = task_cost(&llama_tt(), &A100, &Levers::sdpa_compile()).total;
        let opt = task_cost(&llama_tt(), &A100, &Levers::sys_opt()).total;
        let all = task_cost(&llama_tt(), &A100, &Levers::all()).total;
        assert!(sdpa <= base);
        assert!(cmp < sdpa);
        assert!(opt < cmp);
        assert!(all < opt);
    }

    #[test]
    fn contrastive_doubles_decode() {
        let t1 = TaskSpec::Decoder {
            cfg: &LLAMA_34B,
            batch: 1,
            prompt_len: 14,
            decode_steps: 1024,
            decodes_per_step: 1,
        };
        let t2 = TaskSpec::Decoder {
            cfg: &LLAMA_34B,
            batch: 1,
            prompt_len: 14,
            decode_steps: 1024,
            decodes_per_step: 2,
        };
        let c1 = task_cost(&t1, &A100, &Levers::baseline());
        let c2 = task_cost(&t2, &A100, &Levers::baseline());
        let r = c2.decode_wall / c1.decode_wall;
        assert!(r > 1.8 && r < 2.2, "{r}");
    }

    #[test]
    fn hstu_much_faster_than_ar(){
        let h = TaskSpec::Hstu { cfg: &HSTU_14L, batch: 1, seq: 4814 };
        let ch = task_cost(&h, &A100, &Levers::baseline());
        let cl = task_cost(&llama_tt(), &A100, &Levers::baseline());
        assert!(ch.total < cl.total / 10.0);
    }

    #[test]
    fn seamless_speech_out_slower_than_text_out() {
        let st = TaskSpec::Seamless {
            cfg: &SEAMLESS_M4T,
            src_len: 493,
            text_steps: 36,
            speech_out: false,
            reorder_fused: false,
            speech_in: true,
        };
        let ss = TaskSpec::Seamless {
            cfg: &SEAMLESS_M4T,
            src_len: 493,
            text_steps: 36,
            speech_out: true,
            reorder_fused: false,
            speech_in: true,
        };
        let c_st = task_cost(&st, &A100, &Levers::baseline()).total;
        let c_ss = task_cost(&ss, &A100, &Levers::baseline()).total;
        // paper: S-S ≈ 24% slower than S-T. Our analytical model puts
        // the NAR tail much cheaper (the paper's gap is fairseq2 Python
        // overhead we deliberately do not inflate) — we only assert the
        // *direction* here and record the magnitude in EXPERIMENTS.md.
        assert!(c_ss > c_st * 1.005 && c_ss < c_st * 1.9,
                "{}", c_ss / c_st);
    }

    #[test]
    fn layerskip_speedup_in_paper_band() {
        let s = layerskip_speedup(&LLAMA_34B, LAYERSKIP_ACCEPT);
        assert!(s > 1.3 && s < 2.0, "{s}");
    }
}
