//! Operator-walk builders: each model stage at paper scale as a list of
//! costed operators, categorized exactly like the paper's Figure 4
//! legend (Linear, Attention, Norm, Embedding, Copy/KV_Reorder, Idle…).

use super::configs::{PaperDecoder, PaperHstu, PaperSeamless};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpCategory {
    Linear,
    Attention,
    Norm,
    Embedding,
    /// KV-cache copies (beam reorder, static-cache writes).
    Copy,
    Conv,
    Misc,
}

impl OpCategory {
    pub fn label(self) -> &'static str {
        match self {
            OpCategory::Linear => "Linear",
            OpCategory::Attention => "Attention",
            OpCategory::Norm => "Norm",
            OpCategory::Embedding => "Embedding",
            OpCategory::Copy => "KV_Reorder",
            OpCategory::Conv => "Conv",
            OpCategory::Misc => "Misc",
        }
    }
}

/// One costed operator.
#[derive(Debug, Clone)]
pub struct Op {
    pub cat: OpCategory,
    pub flops: f64,
    pub bytes: f64,
    /// Number of GPU kernels this op launches in eager mode.
    pub kernels: f64,
    /// GEMM-shaped (runs on tensor cores) vs memory/elementwise.
    pub is_gemm: bool,
    /// Integer GEMM (int8 dynamic quant).
    pub is_int8: bool,
}

impl Op {
    pub fn gemm(cat: OpCategory, m: f64, n: f64, k: f64, dt: f64) -> Op {
        Op {
            cat,
            flops: 2.0 * m * n * k,
            bytes: (m * k + k * n + m * n) * dt,
            kernels: 1.0,
            is_gemm: true,
            is_int8: false,
        }
    }
    pub fn elementwise(cat: OpCategory, elems: f64, dt: f64,
                       reads: f64, writes: f64, kernels: f64) -> Op {
        Op {
            cat,
            flops: elems * (reads + writes),
            bytes: elems * dt * (reads + writes),
            kernels,
            is_gemm: false,
            is_int8: false,
        }
    }
}

/// A named operator walk (one logical stage execution).
#[derive(Debug, Clone, Default)]
pub struct OpWalk {
    pub ops: Vec<Op>,
}

impl OpWalk {
    pub fn push(&mut self, op: Op) {
        self.ops.push(op);
    }
    pub fn extend(&mut self, other: OpWalk) {
        self.ops.extend(other.ops);
    }
    pub fn repeat(&self, times: usize) -> OpWalk {
        let mut w = OpWalk::default();
        for _ in 0..times {
            w.ops.extend(self.ops.iter().cloned());
        }
        w
    }
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }
    pub fn total_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.bytes).sum()
    }
    pub fn total_kernels(&self) -> f64 {
        self.ops.iter().map(|o| o.kernels).sum()
    }
}

// ==========================================================================
// Decoder (Llama / Chameleon)
// ==========================================================================

/// Naive-attention core: materialized scores (the SDPA lever's "before").
fn attention_naive(b: f64, h: f64, sq: f64, sk: f64, dh: f64, dt: f64)
                   -> Vec<Op> {
    let scores = b * h * sq * sk;
    vec![
        // QK^T (matmul + transpose/expand/view chain in eager)
        Op {
            cat: OpCategory::Attention,
            flops: 2.0 * scores * dh,
            bytes: (b * h * sq * dh + b * h * sk * dh + scores) * dt,
            kernels: 3.0,
            is_gemm: true,
            is_int8: false,
        },
        // softmax (reads + writes the full score matrix; max/sub/exp/
        // sum/div kernels in eager)
        Op::elementwise(OpCategory::Attention, scores, dt, 2.0, 1.0, 5.0),
        // PV (+ output reshape)
        Op {
            cat: OpCategory::Attention,
            flops: 2.0 * scores * dh,
            bytes: (scores + b * h * sk * dh + b * h * sq * dh) * dt,
            kernels: 3.0,
            is_gemm: true,
            is_int8: false,
        },
    ]
}

/// Flash/SDPA core: no N² materialization; +8% FLOPs for recomputation
/// (paper §4.4), single fused kernel.
fn attention_flash(b: f64, h: f64, sq: f64, sk: f64, dh: f64, dt: f64)
                   -> Vec<Op> {
    let flops = 4.0 * b * h * sq * sk * dh * 1.08;
    let bytes = (2.0 * b * h * sk * dh + 2.0 * b * h * sq * dh) * dt;
    vec![Op {
        cat: OpCategory::Attention,
        flops,
        bytes,
        kernels: 1.0,
        is_gemm: true,
        is_int8: false,
    }]
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKind {
    Naive,
    Flash,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinearKind {
    F32,
    /// int8 weight-only: weight bytes ÷ (dt/1), fp GEMM.
    Int8WeightOnly,
    /// int8 dynamic: weight bytes ÷, int8 tensor-core GEMM.
    Int8Dynamic,
}

fn linear_op(m: f64, n: f64, k: f64, dt: f64, kind: LinearKind) -> Op {
    let mut op = Op::gemm(OpCategory::Linear, m, n, k, dt);
    match kind {
        LinearKind::F32 => {}
        LinearKind::Int8WeightOnly => {
            // weights at 1 byte instead of dt
            op.bytes = (m * k + m * n) * dt + k * n;
        }
        LinearKind::Int8Dynamic => {
            op.bytes = (m * k + m * n) * dt + k * n;
            op.is_int8 = true;
        }
    }
    op
}

/// One decoder-layer walk processing `sq` new tokens against a context
/// of `ctx` tokens (batch `b`).
fn decoder_layer(cfg: &PaperDecoder, b: f64, sq: f64, ctx: f64,
                 attn: AttnKind, lin: LinearKind) -> OpWalk {
    let d = cfg.d_model as f64;
    let f = cfg.ffn_hidden as f64;
    let h = cfg.n_heads as f64;
    let dh = cfg.head_dim as f64;
    let dt = cfg.bytes_per_param as f64;
    let kvd = cfg.kv_dim() as f64;
    let m = b * sq;
    let mut w = OpWalk::default();
    // norms (x2) + rope + residuals: elementwise traffic. Kernel counts
    // reflect PyTorch-eager granularity (each norm ≈ mul/mean/rsqrt/mul
    // chains; rope ≈ split/neg/mul/add chains) — this is what makes
    // bs=1 decode launch-bound (Obs #2).
    w.push(Op::elementwise(OpCategory::Norm, m * d, dt, 2.0, 1.0, 8.0));
    w.push(Op::elementwise(OpCategory::Misc, m * d, dt, 2.0, 1.0, 10.0));
    // q + kv (GQA) + o projections
    w.push(linear_op(m, d + 2.0 * kvd, d, dt, lin));
    w.push(linear_op(m, d, d, dt, lin));
    // attention over ctx keys
    let core = match attn {
        AttnKind::Naive => attention_naive(b, h, sq, ctx, dh, dt),
        AttnKind::Flash => attention_flash(b, h, sq, ctx, dh, dt),
    };
    for op in core {
        w.push(op);
    }
    // KV-cache append (write 2·sq·kv_dim per layer)
    w.push(Op::elementwise(OpCategory::Copy, m * 2.0 * kvd, dt, 1.0,
                           1.0, 2.0));
    // SwiGLU FFN: gate, up, down + glu elementwise
    w.push(linear_op(m, f, d, dt, lin));
    w.push(linear_op(m, f, d, dt, lin));
    w.push(linear_op(m, d, f, dt, lin));
    w.push(Op::elementwise(OpCategory::Misc, m * f, dt, 2.0, 1.0, 3.0));
    w
}

/// Full prefill walk (`seq` prompt tokens, batch `b`).
pub fn decoder_prefill(cfg: &PaperDecoder, b: usize, seq: usize,
                       attn: AttnKind, lin: LinearKind) -> OpWalk {
    let mut w = OpWalk::default();
    let dt = cfg.bytes_per_param as f64;
    let m = (b * seq) as f64;
    let d = cfg.d_model as f64;
    w.push(Op::elementwise(OpCategory::Embedding, m * d, dt, 1.0, 1.0, 1.0));
    let layer = decoder_layer(cfg, b as f64, seq as f64, seq as f64, attn,
                              lin);
    w.extend(layer.repeat(cfg.n_layers));
    // LM head on the last position only
    w.push(linear_op(b as f64, cfg.vocab as f64, d, dt, lin));
    w
}

/// One decode step at context length `ctx` (batch `b`).
pub fn decoder_decode_step(cfg: &PaperDecoder, b: usize, ctx: usize,
                           attn: AttnKind, lin: LinearKind) -> OpWalk {
    let mut w = OpWalk::default();
    let dt = cfg.bytes_per_param as f64;
    let d = cfg.d_model as f64;
    w.push(Op::elementwise(OpCategory::Embedding, (b as f64) * d, dt, 1.0,
                           1.0, 1.0));
    let layer =
        decoder_layer(cfg, b as f64, 1.0, ctx as f64, attn, lin);
    w.extend(layer.repeat(cfg.n_layers));
    w.push(linear_op(b as f64, cfg.vocab as f64, d, dt, lin));
    w
}

// ==========================================================================
// Seamless
// ==========================================================================

/// Conformer speech-encoder walk over `t` frames (post-subsample length).
pub fn seamless_encoder(cfg: &PaperSeamless, t: usize, attn: AttnKind)
                        -> OpWalk {
    let d = cfg.d_model as f64;
    let f = cfg.ffn_hidden as f64;
    let h = cfg.n_heads as f64;
    let dh = cfg.head_dim as f64;
    let dt = cfg.bytes_per_param as f64;
    let tf = t as f64;
    let mut w = OpWalk::default();
    w.push(Op::gemm(OpCategory::Linear, tf, d, 320.0, dt)); // front-end
    for _ in 0..cfg.enc_layers {
        // ½ffn ×2
        for _ in 0..2 {
            w.push(Op::gemm(OpCategory::Linear, tf, f, d, dt));
            w.push(Op::gemm(OpCategory::Linear, tf, d, f, dt));
        }
        // MHSA
        w.push(Op::gemm(OpCategory::Linear, tf, 3.0 * d, d, dt));
        w.push(Op::gemm(OpCategory::Linear, tf, d, d, dt));
        let core = match attn {
            AttnKind::Naive => attention_naive(1.0, h, tf, tf, dh, dt),
            AttnKind::Flash => attention_flash(1.0, h, tf, tf, dh, dt),
        };
        for op in core {
            w.push(op);
        }
        // conv module: pw-glu, depthwise(k=31), pw
        w.push(Op::gemm(OpCategory::Conv, tf, 2.0 * d, d, dt));
        w.push(Op::elementwise(OpCategory::Conv, tf * d * 31.0, dt, 1.0,
                               0.1, 1.0));
        w.push(Op::gemm(OpCategory::Conv, tf, d, d, dt));
        // norms
        w.push(Op::elementwise(OpCategory::Norm, tf * d, dt, 2.0, 1.0, 5.0));
    }
    w
}

/// One text-decoder beam step: self-attn over `ctx`, cross-attn over
/// `src`, beam batch `bm`.
pub fn seamless_dec_step(cfg: &PaperSeamless, bm: usize, ctx: usize,
                         src: usize, attn: AttnKind) -> OpWalk {
    let d = cfg.d_model as f64;
    let f = cfg.ffn_hidden as f64;
    let h = cfg.n_heads as f64;
    let dh = cfg.head_dim as f64;
    let dt = cfg.bytes_per_param as f64;
    let b = bm as f64;
    let mut w = OpWalk::default();
    w.push(Op::elementwise(OpCategory::Embedding, b * d, dt, 1.0, 1.0, 1.0));
    for _ in 0..cfg.dec_layers {
        // self-attn
        w.push(Op::gemm(OpCategory::Linear, b, 3.0 * d, d, dt));
        w.push(Op::gemm(OpCategory::Linear, b, d, d, dt));
        for op in match attn {
            AttnKind::Naive => attention_naive(b, h, 1.0, ctx as f64, dh, dt),
            AttnKind::Flash => attention_flash(b, h, 1.0, ctx as f64, dh, dt),
        } {
            w.push(op);
        }
        // cross-attn (k/v precomputed: only q + o projections)
        w.push(Op::gemm(OpCategory::Linear, b, d, d, dt));
        w.push(Op::gemm(OpCategory::Linear, b, d, d, dt));
        for op in match attn {
            AttnKind::Naive => attention_naive(b, h, 1.0, src as f64, dh, dt),
            AttnKind::Flash => attention_flash(b, h, 1.0, src as f64, dh, dt),
        } {
            w.push(op);
        }
        // ffn
        w.push(Op::gemm(OpCategory::Linear, b, f, d, dt));
        w.push(Op::gemm(OpCategory::Linear, b, d, f, dt));
        w.push(Op::elementwise(OpCategory::Norm, b * d, dt, 2.0, 1.0, 6.0));
    }
    // lm head
    w.push(Op::gemm(OpCategory::Linear, b, cfg.text_vocab as f64, d, dt));
    w
}

/// Beam-search KV reorder at context `ctx`: copy the whole self-cache
/// (the Obs-#4 `index_select`). `fused` models the compiled in-place
/// gather (single kernel, same bytes, no allocation round-trip —
/// kernels collapse 2L→1).
pub fn seamless_kv_reorder(cfg: &PaperSeamless, bm: usize, ctx: usize,
                           fused: bool) -> OpWalk {
    let bytes = cfg.kv_bytes_per_token() * (bm * ctx) as f64;
    let mut w = OpWalk::default();
    w.push(Op {
        cat: OpCategory::Copy,
        flops: 0.0,
        bytes: 2.0 * bytes, // read + write
        kernels: if fused { 1.0 } else { 2.0 * cfg.dec_layers as f64 },
        is_gemm: false,
        is_int8: false,
    });
    w
}

/// NAR T2U over `text_len` tokens.
pub fn seamless_t2u(cfg: &PaperSeamless, text_len: usize) -> OpWalk {
    let d = cfg.d_model as f64;
    let f = cfg.ffn_hidden as f64;
    let h = cfg.n_heads as f64;
    let dh = cfg.head_dim as f64;
    let dt = cfg.bytes_per_param as f64;
    let u = (text_len * cfg.t2u_upsample) as f64;
    let mut w = OpWalk::default();
    for _ in 0..cfg.t2u_layers {
        w.push(Op::gemm(OpCategory::Linear, u, 3.0 * d, d, dt));
        w.push(Op::gemm(OpCategory::Linear, u, d, d, dt));
        for op in attention_naive(1.0, h, u, u, dh, dt) {
            w.push(op);
        }
        w.push(Op::gemm(OpCategory::Linear, u, f, d, dt));
        w.push(Op::gemm(OpCategory::Linear, u, d, f, dt));
    }
    w.push(Op::gemm(OpCategory::Linear, u, cfg.unit_vocab as f64, d, dt));
    w
}

/// HiFi-GAN vocoder over `units` (conv upsampling stack with MRF
/// residual blocks). Each stage = 1 transposed conv + 3 resblocks × 3
/// dilated convs; every conv in eager PyTorch is a pad/conv/bias/act
/// kernel chain — this module is the paper's launch-overhead poster
/// child (30× from compile+CUDA Graph, §4.1.2 deep dive).
pub fn seamless_vocoder(cfg: &PaperSeamless, units: usize) -> OpWalk {
    let dt = cfg.bytes_per_param as f64;
    let mut w = OpWalk::default();
    let mut len = units as f64;
    let mut ch = cfg.voc_channels as f64;
    for _ in 0..cfg.voc_stages {
        len *= cfg.voc_upsample as f64;
        let next = (ch / 2.0).max(8.0);
        // upsampling transposed conv k=2·rate
        let mut up = Op::gemm(OpCategory::Conv, len, next,
                              2.0 * cfg.voc_upsample as f64 * ch, dt);
        up.kernels = 4.0;
        w.push(up);
        ch = next;
        // MRF: 3 resblocks × 3 dilated convs, k=3|7|11
        for k in [3.0, 7.0, 11.0] {
            for _ in 0..3 {
                let mut c = Op::gemm(OpCategory::Conv, len, ch, k * ch, dt);
                c.kernels = 4.0; // pad + conv + bias + leaky_relu
                w.push(c);
            }
        }
    }
    let mut head = Op::gemm(OpCategory::Conv, len, 1.0, 7.0 * ch, dt);
    head.kernels = 3.0;
    w.push(head);
    w
}

// ==========================================================================
// HSTU
// ==========================================================================

/// HSTU forward over `seq` history items, batch `b`. `fused` applies the
/// §4.1.1 kernel (no rel-bias materialization, grouped GEMMs — modeled
/// as flash-style traffic).
pub fn hstu_forward(cfg: &PaperHstu, b: usize, seq: usize, fused: bool)
                    -> OpWalk {
    let d = cfg.d_model as f64;
    let hs = (cfg.n_heads * cfg.head_dim) as f64;
    let h = cfg.n_heads as f64;
    let dh = cfg.head_dim as f64;
    let dt = cfg.bytes_per_param as f64;
    let bf = b as f64;
    let mut w = OpWalk::default();
    for l in 0..cfg.n_layers {
        let s = if l < cfg.full_len_layers {
            seq
        } else {
            seq.min(cfg.capped_len)
        } as f64;
        let m = bf * s;
        // pointwise projection (fused U|V|Q|K)
        w.push(Op::gemm(OpCategory::Linear, m, 3.0 * hs + d, d, dt));
        // spatial aggregation: silu(qk+rab)·v
        if fused {
            for op in attention_flash(bf, h, s, s, dh, dt) {
                w.push(op);
            }
        } else {
            for mut op in attention_naive(bf, h, s, s, dh, dt) {
                // rel-bias materialization adds an extra [h,s,s] read+write
                if !op.is_gemm {
                    op.bytes *= 2.0;
                    op.kernels += 1.0;
                }
                w.push(op);
            }
        }
        // pointwise transformation: norm, gate, output linear
        w.push(Op::elementwise(OpCategory::Norm, m * hs, dt, 2.0, 1.0, 2.0));
        w.push(Op::gemm(OpCategory::Linear, m, d, hs, dt));
    }
    w
}

#[cfg(test)]
mod tests {
    use super::super::configs::{HSTU_14L, LLAMA_7B, SEAMLESS_M4T};
    use super::*;

    #[test]
    fn prefill_flops_scale_quadratically_in_attention() {
        let w1 = decoder_prefill(&LLAMA_7B, 1, 512, AttnKind::Naive,
                                 LinearKind::F32);
        let w2 = decoder_prefill(&LLAMA_7B, 1, 1024, AttnKind::Naive,
                                 LinearKind::F32);
        let attn = |w: &OpWalk| -> f64 {
            w.ops
                .iter()
                .filter(|o| o.cat == OpCategory::Attention)
                .map(|o| o.flops)
                .sum()
        };
        let r = attn(&w2) / attn(&w1);
        assert!(r > 3.5 && r < 4.5, "attention should be ~O(N²): {r}");
    }

    #[test]
    fn decode_step_is_memory_bound() {
        // bs=1 decode: bytes/bw time must exceed flops/peak by a lot
        let w = decoder_decode_step(&LLAMA_7B, 1, 1024, AttnKind::Naive,
                                    LinearKind::F32);
        let t_flops = w.total_flops() / 156e12;
        let t_bytes = w.total_bytes() / 2.0e12;
        assert!(t_bytes > 10.0 * t_flops, "{t_bytes} vs {t_flops}");
    }

    #[test]
    fn decode_reads_roughly_the_weights() {
        // bs=1 decode traffic ≈ weight bytes (the classic LLM bound).
        let w = decoder_decode_step(&LLAMA_7B, 1, 128, AttnKind::Naive,
                                    LinearKind::F32);
        let wb = LLAMA_7B.weight_bytes();
        let r = w.total_bytes() / wb;
        assert!(r > 0.8 && r < 1.5, "{r}");
    }

    #[test]
    fn flash_cuts_attention_bytes() {
        let n: f64 = attention_naive(1.0, 32.0, 2048.0, 2048.0, 128.0, 2.0)
            .iter()
            .map(|o| o.bytes)
            .sum();
        let f: f64 = attention_flash(1.0, 32.0, 2048.0, 2048.0, 128.0, 2.0)
            .iter()
            .map(|o| o.bytes)
            .sum();
        assert!(f < n / 4.0, "flash {f} vs naive {n}");
    }

    #[test]
    fn int8_weight_only_cuts_linear_bytes() {
        let a = linear_op(1.0, 4096.0, 4096.0, 2.0, LinearKind::F32);
        let b = linear_op(1.0, 4096.0, 4096.0, 2.0,
                          LinearKind::Int8WeightOnly);
        assert!(b.bytes < a.bytes * 0.6);
        assert_eq!(a.flops, b.flops);
    }

    #[test]
    fn hstu_attention_dominates() {
        // Paper: >90% of HSTU time is attention (large seq).
        let w = hstu_forward(&HSTU_14L, 1, 4814, false);
        let attn: f64 = w
            .ops
            .iter()
            .filter(|o| o.cat == OpCategory::Attention)
            .map(|o| o.flops)
            .sum();
        // >90% in *time* (see breakdown tests); in raw FLOPs the bar is
        // lower because later layers are capped at 1024.
        assert!(attn / w.total_flops() > 0.55, "{}", attn / w.total_flops());
    }

    #[test]
    fn kv_reorder_fused_same_bytes_fewer_kernels() {
        let a = seamless_kv_reorder(&SEAMLESS_M4T, 5, 30, false);
        let b = seamless_kv_reorder(&SEAMLESS_M4T, 5, 30, true);
        assert_eq!(a.total_bytes(), b.total_bytes());
        assert!(b.total_kernels() < a.total_kernels());
    }
}
