//! Priced transfer fabric: the link-bandwidth/latency model for KV
//! movement.
//!
//! Sharding and preemption left three kinds of KV movement counted but
//! unpriced: cross-shard page gathers (`PoolStats::shard_spills`),
//! swap-out host copies (a token-count ledger), and — once the fleet
//! is split into prefill and decode workers — the prefill→decode KV
//! handoff. This module models each as bytes over a link:
//!
//! ```text
//! t_link(bytes) = latency_ns + bytes / bandwidth * 1e9
//! ```
//!
//! with three links at public interconnect magnitudes: NVLink for
//! intra-node cross-shard gathers, PCIe for the host swap path, and
//! datacenter Ethernet for inter-replica handoff. Bytes come from the
//! model family's KV geometry (`PaperDecoder::kv_bytes_per_token`), so
//! one page of 16 Llama-7B tokens is ~8 MB and a 150-token handoff is
//! ~75 MB — transfers are bandwidth-bound, exactly the shape the
//! multimodal characterization measures for inter-accelerator traffic.
//!
//! Costs are returned both in nanoseconds and in *simulated clock
//! units* (one decode tick == [`SIM_UNIT_NS`]), so the replay drivers
//! can charge them on the same clock that prices prefill and decode
//! compute. The whole model is a plain value type: a zero-cost fabric
//! ([`FabricSpec::zero_cost`]) makes every comparison a tie, and every
//! consumer breaks ties toward the legacy behavior — the bisimulation
//! guard the property suite enforces.

/// Which link a transfer crosses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// GPU↔GPU inside one node (cross-shard page gather).
    NvLink,
    /// GPU↔host (swap-out / swap-in over the host buffer pool).
    Pcie,
    /// Replica↔replica over the datacenter network (KV handoff).
    Network,
}

/// One link: sustained bandwidth plus a fixed per-transfer latency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    pub bandwidth_bytes_per_sec: f64,
    pub latency_ns: f64,
}

impl LinkSpec {
    /// Wall nanoseconds to move `bytes` across this link.
    pub fn transfer_ns(&self, bytes: u64) -> f64 {
        if self.bandwidth_bytes_per_sec <= 0.0 {
            return self.latency_ns;
        }
        self.latency_ns
            + bytes as f64 / self.bandwidth_bytes_per_sec * 1e9
    }
}

/// NVLink 3.0-class intra-node link (~300 GB/s effective).
pub const NVLINK: LinkSpec = LinkSpec {
    bandwidth_bytes_per_sec: 300.0e9,
    latency_ns: 2_000.0,
};

/// PCIe gen4 x16-class host link (~32 GB/s effective).
pub const PCIE_GEN4: LinkSpec = LinkSpec {
    bandwidth_bytes_per_sec: 32.0e9,
    latency_ns: 5_000.0,
};

/// 100 GbE-class inter-replica network (~12.5 GB/s line rate).
pub const ETH_100G: LinkSpec = LinkSpec {
    bandwidth_bytes_per_sec: 12.5e9,
    latency_ns: 10_000.0,
};

/// Simulated-clock conversion: one decode tick (cost 1.0 on the replay
/// clock) models ~20 ms of wall time — the right magnitude for a
/// batched 7B decode step on an A100.
pub const SIM_UNIT_NS: f64 = 2.0e7;

/// The complete priced fabric: one spec per link kind plus the KV
/// geometry that turns tokens/pages into bytes and the recompute rate
/// that swap-vs-recompute decisions compare against.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FabricSpec {
    /// Cross-shard gathers inside one node.
    pub intra_node: LinkSpec,
    /// The swap path to host memory.
    pub host_link: LinkSpec,
    /// Prefill→decode KV handoff between replicas.
    pub inter_replica: LinkSpec,
    /// KV bytes per token for the served model family
    /// (`PaperDecoder::kv_bytes_per_token`).
    pub kv_bytes_per_token: f64,
    /// Modeled nanoseconds to recompute (re-prefill) one token — what
    /// a swap transfer is traded against. On the replay clock one
    /// prefill token costs 0.05 sim units == 1e6 ns.
    pub recompute_ns_per_token: f64,
    /// Host swap buffer capacity in bytes (0 = unbounded): a failed
    /// reservation falls back to recompute, which is what makes the
    /// swap-vs-recompute decision mix a real policy output.
    pub host_capacity_bytes: u64,
}

impl FabricSpec {
    /// All-zero fabric: every transfer is free and every cost
    /// comparison ties. Consumers break ties toward the legacy
    /// behavior, so this spec is bit-identical to running without a
    /// fabric at all (the bisimulation guard).
    pub fn zero_cost() -> Self {
        let free = LinkSpec { bandwidth_bytes_per_sec: 0.0,
                              latency_ns: 0.0 };
        FabricSpec {
            intra_node: free,
            host_link: free,
            inter_replica: free,
            kv_bytes_per_token: 0.0,
            recompute_ns_per_token: 0.0,
            host_capacity_bytes: 0,
        }
    }

    /// Paper-scale defaults over a given KV geometry: NVLink inside
    /// the node, PCIe gen4 to host, 100 GbE between replicas, 256 MiB
    /// of host swap buffers.
    pub fn paper(kv_bytes_per_token: f64) -> Self {
        FabricSpec {
            intra_node: NVLINK,
            host_link: PCIE_GEN4,
            inter_replica: ETH_100G,
            kv_bytes_per_token,
            recompute_ns_per_token: 1.0e6,
            host_capacity_bytes: 256 << 20,
        }
    }

    /// True when every link and rate is zero (tie-everywhere fabric).
    pub fn is_free(&self) -> bool {
        let free = |l: &LinkSpec| {
            l.bandwidth_bytes_per_sec == 0.0 && l.latency_ns == 0.0
        };
        free(&self.intra_node)
            && free(&self.host_link)
            && free(&self.inter_replica)
            && self.kv_bytes_per_token == 0.0
            && self.recompute_ns_per_token == 0.0
    }

    pub fn link(&self, kind: LinkKind) -> &LinkSpec {
        match kind {
            LinkKind::NvLink => &self.intra_node,
            LinkKind::Pcie => &self.host_link,
            LinkKind::Network => &self.inter_replica,
        }
    }

    /// KV bytes held by `tokens` tokens of cache.
    pub fn bytes_for_tokens(&self, tokens: usize) -> u64 {
        (tokens as f64 * self.kv_bytes_per_token) as u64
    }

    /// KV bytes held by `pages` pages of `page_size` tokens each.
    pub fn bytes_for_pages(&self, pages: usize, page_size: usize) -> u64 {
        self.bytes_for_tokens(pages * page_size)
    }

    /// Wall nanoseconds for one transfer of `bytes` over `kind`.
    pub fn transfer_ns(&self, kind: LinkKind, bytes: u64) -> f64 {
        self.link(kind).transfer_ns(bytes)
    }

    /// The same transfer priced in simulated clock units.
    pub fn transfer_cost(&self, kind: LinkKind, bytes: u64) -> f64 {
        self.transfer_ns(kind, bytes) / SIM_UNIT_NS
    }

    /// Re-prefilling `tokens` tokens, in simulated clock units.
    pub fn recompute_cost(&self, tokens: usize) -> f64 {
        tokens as f64 * self.recompute_ns_per_token / SIM_UNIT_NS
    }

    /// One direction of the host swap path for `tokens` tokens, in
    /// simulated clock units (a full swap round-trip is out + in).
    pub fn swap_cost(&self, tokens: usize) -> f64 {
        self.transfer_cost(LinkKind::Pcie, self.bytes_for_tokens(tokens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::configs::LLAMA_7B;

    #[test]
    fn zero_cost_fabric_prices_everything_at_zero() {
        let f = FabricSpec::zero_cost();
        assert!(f.is_free());
        assert_eq!(f.bytes_for_tokens(1000), 0);
        assert_eq!(f.transfer_cost(LinkKind::NvLink, 0), 0.0);
        assert_eq!(f.transfer_cost(LinkKind::Pcie, 0), 0.0);
        assert_eq!(f.swap_cost(500), 0.0);
        assert_eq!(f.recompute_cost(500), 0.0);
        // The bisimulation tie: swap is never *strictly* cheaper.
        assert!(!(f.swap_cost(128) * 2.0 < f.recompute_cost(128)));
    }

    #[test]
    fn llama7b_geometry_makes_transfers_bandwidth_bound() {
        let f = FabricSpec::paper(LLAMA_7B.kv_bytes_per_token());
        assert!(!f.is_free());
        // 32 layers × 2 (K,V) × 4096 dim × 2 bytes = 0.5 MB/token.
        assert_eq!(f.bytes_for_tokens(1), 524_288);
        // One 16-token page over NVLink: dominated by bytes/bandwidth,
        // not the fixed latency.
        let page = f.bytes_for_pages(1, 16);
        let ns = f.transfer_ns(LinkKind::NvLink, page);
        assert!(ns > 2.0 * NVLINK.latency_ns, "{ns}");
        // Ordering: NVLink < PCIe < network for the same bytes.
        assert!(f.transfer_ns(LinkKind::NvLink, page)
                    < f.transfer_ns(LinkKind::Pcie, page));
        assert!(f.transfer_ns(LinkKind::Pcie, page)
                    < f.transfer_ns(LinkKind::Network, page));
    }

    #[test]
    fn swap_beats_recompute_and_handoff_beats_reprefill_at_7b() {
        let f = FabricSpec::paper(LLAMA_7B.kv_bytes_per_token());
        // A 150-token sequence: the full swap round-trip (~5 ms over
        // PCIe) is far cheaper than re-prefilling (~150 ms modeled).
        let swap = 2.0 * f.swap_cost(150);
        let recompute = f.recompute_cost(150);
        assert!(swap < recompute, "swap {swap} vs recompute {recompute}");
        // Shipping the same KV over the network into a decode worker
        // also beats re-prefilling it there — disaggregation's margin.
        let handoff =
            f.transfer_cost(LinkKind::Network, f.bytes_for_tokens(150));
        assert!(handoff < recompute, "{handoff} vs {recompute}");
        // But none of it is free: the handoff is a real, non-zero TTFT
        // charge on the simulated clock.
        assert!(handoff > 0.1, "{handoff}");
    }
}
