//! Optimization-lever configuration for the device model + the core
//! time-costing functions (eager vs graph launch discipline).

use super::device::DeviceSpec;
use super::ops::{AttnKind, LinearKind, Op, OpWalk};
use crate::substrate::metrics::OpTimes;

/// Which §4 levers are enabled for a model-walk evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Levers {
    pub sdpa: bool,
    /// torch.compile + CUDA Graph: one captured graph per step instead of
    /// per-op launches; elementwise chains fuse.
    pub compile: bool,
    pub quant: Option<QuantKind>,
    pub layerskip: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantKind {
    WeightOnly,
    Dynamic,
}

impl Levers {
    pub fn baseline() -> Self {
        Levers { sdpa: false, compile: false, quant: None, layerskip: false }
    }
    pub fn sdpa() -> Self {
        Levers { sdpa: true, ..Self::baseline() }
    }
    pub fn sdpa_compile() -> Self {
        Levers { sdpa: true, compile: true, ..Self::baseline() }
    }
    pub fn sys_opt() -> Self {
        Levers {
            sdpa: true,
            compile: true,
            quant: Some(QuantKind::WeightOnly),
            layerskip: false,
        }
    }
    pub fn all() -> Self {
        Levers { layerskip: true, ..Self::sys_opt() }
    }

    pub fn attn_kind(&self) -> AttnKind {
        if self.sdpa {
            AttnKind::Flash
        } else {
            AttnKind::Naive
        }
    }
    pub fn linear_kind(&self) -> LinearKind {
        match self.quant {
            None => LinearKind::F32,
            Some(QuantKind::WeightOnly) => LinearKind::Int8WeightOnly,
            Some(QuantKind::Dynamic) => LinearKind::Int8Dynamic,
        }
    }
    pub fn label(&self) -> String {
        let mut parts = vec![];
        if self.sdpa {
            parts.push("SDPA");
        }
        if self.compile {
            parts.push("compile+graph");
        }
        if self.quant.is_some() {
            parts.push("AutoQuant");
        }
        if self.layerskip {
            parts.push("LayerSkip");
        }
        if parts.is_empty() {
            "baseline".to_string()
        } else {
            parts.join("+")
        }
    }
}

/// GPU busy time of one operator on a device.
pub fn op_gpu_time(op: &Op, dev: &DeviceSpec) -> f64 {
    let peak = if op.is_int8 {
        dev.peak_int8
    } else if op.is_gemm {
        dev.peak_tensor
    } else {
        dev.peak_f32
    };
    let t_c = op.flops / (peak * dev.gemm_eff);
    let t_m = op.bytes / (dev.hbm_bw * dev.mem_eff);
    t_c.max(t_m)
}

/// Cost a whole walk under a launch discipline. Returns (wall, times)
/// where `times` carries per-category busy time plus the "Idle" bucket —
/// exactly the Figure-4 decomposition.
pub fn cost_walk(walk: &OpWalk, dev: &DeviceSpec, compiled: bool)
                 -> (f64, OpTimes) {
    let mut times = OpTimes::new();
    let mut busy = 0.0;
    let mut wall = 0.0;
    if compiled {
        // One captured graph: GPU runs back-to-back; elementwise chains
        // fuse (kernels collapse ⇒ their launch cost vanishes).
        for op in &walk.ops {
            let t = op_gpu_time(op, dev);
            times.add(op.cat.label(), t);
            busy += t;
        }
        wall = busy.max(dev.graph_launch) + dev.graph_launch;
        let idle = wall - busy;
        if idle > 0.0 {
            times.add("Idle", idle);
        }
    } else {
        // Eager: each kernel pays CPU launch; the GPU sits idle whenever
        // the kernel finishes before the CPU can issue the next one.
        for op in &walk.ops {
            let t = op_gpu_time(op, dev);
            let launches = op.kernels.max(1.0);
            let step = t.max(launches * dev.launch_overhead);
            times.add(op.cat.label(), t);
            busy += t;
            wall += step;
        }
        let idle = wall - busy;
        if idle > 0.0 {
            times.add("Idle", idle);
        }
    }
    (wall, times)
}

/// GPU utilization (busy / wall) for a costed walk.
pub fn utilization(walk: &OpWalk, dev: &DeviceSpec, compiled: bool) -> f64 {
    let (wall, times) = cost_walk(walk, dev, compiled);
    let idle = times.get("Idle");
    ((wall - idle) / wall).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::super::configs::LLAMA_7B;
    use super::super::device::A100;
    use super::super::ops::{decoder_decode_step, AttnKind, LinearKind};
    use super::*;

    #[test]
    fn eager_decode_is_launch_bound_compile_fixes_it() {
        // Obs #2: bs=1 decode eager wall >> busy; graph mode ≈ busy.
        let w = decoder_decode_step(&LLAMA_7B, 1, 512, AttnKind::Naive,
                                    LinearKind::F32);
        let (wall_e, times_e) = cost_walk(&w, &A100, false);
        let (wall_g, _) = cost_walk(&w, &A100, true);
        assert!(times_e.get("Idle") > 0.0);
        assert!(wall_g < wall_e, "graph {wall_g} !< eager {wall_e}");
    }

    #[test]
    fn utilization_higher_when_compiled() {
        let w = decoder_decode_step(&LLAMA_7B, 1, 512, AttnKind::Naive,
                                    LinearKind::F32);
        assert!(
            utilization(&w, &A100, true) > utilization(&w, &A100, false)
        );
    }

    #[test]
    fn lever_labels() {
        assert_eq!(Levers::baseline().label(), "baseline");
        assert_eq!(Levers::sys_opt().label(), "SDPA+compile+graph+AutoQuant");
    }
}
