//! Analytical A100/H100 device performance model.
//!
//! The paper's GPU-side results (Figs 1, 3–11) are regenerated here by
//! walking each model's operator graph at paper scale and costing every
//! operator with the classic roofline rule
//!
//! ```text
//! t_gpu(op)  = max(flops / peak_flops, bytes / hbm_bw) / efficiency
//! t_step     = Σ max(t_gpu, t_launch)        (eager: launch-bound ops
//!                                             leave the GPU idle — Obs #2)
//!            | max(Σ t_gpu, t_graph_launch)  (graph/CUDA-Graph mode)
//! ```
//!
//! The optimization levers (§4) are modeled as operator-walk transforms:
//! SDPA changes attention's memory traffic (+8% FLOPs, paper §4.4),
//! torch.compile+CUDA Graph changes the launch discipline and fuses
//! element-wise ops, AutoQuant shrinks weight bytes (and switches the
//! GEMM peak for dynamic int8), LayerSkip scales the per-token cost by
//! the draft/verify economics. Device parameters come from public
//! A100/H100 specs; nothing is fitted to the paper's numbers.

pub mod breakdown;
pub mod configs;
pub mod device;
pub mod fabric;
pub mod latency;
pub mod levers;
pub mod ops;
pub mod requirements;
pub mod roofline;

pub use configs::{PaperDecoder, PaperHstu, PaperSeamless};
pub use device::DeviceSpec;
pub use fabric::{FabricSpec, LinkKind, LinkSpec};
pub use levers::Levers;
pub use ops::{Op, OpCategory, OpWalk};

use crate::models::TaskKind;
use crate::workload;

/// The Figure-4 task set at paper scale (shared by the CLI and the
/// fig04/fig10 benches).
pub fn standard_breakdown_rows(dev: &DeviceSpec, lv: &Levers)
                               -> Vec<breakdown::Breakdown> {
    use breakdown::breakdown;
    use latency::TaskSpec;
    let t2 = workload::spec_for;
    let mut rows = Vec::new();
    let tt = t2(TaskKind::TextToText);
    rows.push(breakdown(
        "Llama T-T",
        &TaskSpec::Decoder {
            cfg: &configs::LLAMA_34B,
            batch: 4,
            prompt_len: tt.input.avg as usize,
            decode_steps: tt.decode_steps as usize,
            decodes_per_step: 1,
        },
        dev, lv,
    ));
    let it = t2(TaskKind::ImageToText);
    rows.push(breakdown(
        "CM3 I-T",
        &TaskSpec::Decoder {
            cfg: &configs::CHAMELEON_34B,
            batch: 16,
            prompt_len: it.input.avg as usize,
            decode_steps: it.decode_steps as usize,
            decodes_per_step: 1,
        },
        dev, lv,
    ));
    let ti = t2(TaskKind::TextToImage);
    rows.push(breakdown(
        "CM3 T-I",
        &TaskSpec::Decoder {
            cfg: &configs::CHAMELEON_34B,
            batch: 16,
            prompt_len: ti.input.avg as usize,
            decode_steps: ti.decode_steps as usize,
            decodes_per_step: 2,
        },
        dev, lv,
    ));
    let ss = t2(TaskKind::SpeechToSpeech);
    rows.push(breakdown(
        "Seamless S-S",
        &TaskSpec::Seamless {
            cfg: &configs::SEAMLESS_M4T,
            src_len: ss.input.avg as usize,
            text_steps: ss.decode_steps as usize,
            speech_out: true,
            reorder_fused: false,
            speech_in: true,
        },
        dev, lv,
    ));
    let ha = t2(TaskKind::HistoryToAction);
    rows.push(breakdown(
        "HSTU H-A",
        &TaskSpec::Hstu {
            cfg: &configs::HSTU_14L,
            batch: 32,
            seq: ha.input.avg as usize,
        },
        dev, lv,
    ));
    rows
}
