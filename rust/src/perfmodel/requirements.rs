//! Figure 1: per-task system requirements — end-to-end latency, GPU
//! utilization, memory capacity, and compute demand.

use super::device::DeviceSpec;
use super::latency::{task_cost, TaskSpec};
use super::levers::Levers;

#[derive(Debug, Clone)]
pub struct Requirements {
    pub label: String,
    pub latency_s: f64,
    /// Busy / wall over the whole sample.
    pub gpu_utilization: f64,
    /// Weights + KV + activation working set, bytes.
    pub memory_bytes: f64,
    /// Total FLOPs for one sample.
    pub compute_flops: f64,
}

/// Memory requirement for a spec (weights + KV at final context).
pub fn memory_bytes(spec: &TaskSpec) -> f64 {
    match *spec {
        TaskSpec::Decoder { cfg, batch, prompt_len, decode_steps,
                            decodes_per_step } => {
            let ctx = (prompt_len + decode_steps) as f64;
            cfg.weight_bytes()
                + decodes_per_step as f64
                    * batch as f64 * ctx * cfg.kv_bytes_per_token()
        }
        TaskSpec::Seamless { cfg, src_len, text_steps, .. } => {
            cfg.weight_bytes()
                + cfg.beam as f64
                    * text_steps as f64 * cfg.kv_bytes_per_token()
                + (src_len * cfg.d_model * 2) as f64
        }
        TaskSpec::Hstu { cfg, batch, seq } => {
            cfg.weight_bytes()
                + (batch * seq * cfg.d_model * 2 * 4) as f64 // activations
        }
    }
}

pub fn requirements(label: &str, spec: &TaskSpec, dev: &DeviceSpec,
                    lv: &Levers) -> Requirements {
    let c = task_cost(spec, dev, lv);
    let idle = c.prefill_times.get("Idle") + c.decode_times.get("Idle");
    let busy = (c.total - idle).max(0.0);
    Requirements {
        label: label.to_string(),
        latency_s: c.total,
        gpu_utilization: (busy / c.total.max(1e-12)).clamp(0.0, 1.0),
        memory_bytes: memory_bytes(spec),
        compute_flops: c.flops,
    }
}

#[cfg(test)]
mod tests {
    use super::super::configs::{CHAMELEON_34B, HSTU_14L};
    use super::super::device::A100;
    use super::*;

    #[test]
    fn ti_task_demands_most() {
        // Fig 1: Chameleon T-I is the heaviest task across the axes.
        let ti = TaskSpec::Decoder {
            cfg: &CHAMELEON_34B,
            batch: 1,
            prompt_len: 14,
            decode_steps: 1024,
            decodes_per_step: 2,
        };
        let it = TaskSpec::Decoder {
            cfg: &CHAMELEON_34B,
            batch: 1,
            prompt_len: 1040,
            decode_steps: 10,
            decodes_per_step: 1,
        };
        let r_ti = requirements("T-I", &ti, &A100, &Levers::baseline());
        let r_it = requirements("IT-T", &it, &A100, &Levers::baseline());
        assert!(r_ti.latency_s > 5.0 * r_it.latency_s);
        assert!(r_ti.compute_flops > r_it.compute_flops);
        assert!(r_ti.memory_bytes > r_it.memory_bytes);
    }

    #[test]
    fn hstu_high_utilization() {
        // Obs #2: HSTU's big batched matmuls keep the GPU busy.
        let h = TaskSpec::Hstu { cfg: &HSTU_14L, batch: 32, seq: 4814 };
        let r = requirements("H-A", &h, &A100, &Levers::baseline());
        assert!(r.gpu_utilization > 0.5, "{}", r.gpu_utilization);
    }
}
