//! Figure 4 / Figure 10: operator time breakdown per task, split by
//! prefill vs decode, with the Idle bucket.

use crate::substrate::metrics::OpTimes;
use crate::substrate::table::Table;

use super::device::DeviceSpec;
use super::latency::{task_cost, TaskSpec};
use super::levers::Levers;

pub const CATEGORIES: [&str; 8] = [
    "Linear", "Attention", "Norm", "Embedding", "KV_Reorder", "Conv",
    "Misc", "Idle",
];

#[derive(Debug, Clone)]
pub struct Breakdown {
    pub label: String,
    pub phase_times: Vec<(String, OpTimes)>,
    pub total: f64,
}

/// Compute the prefill/decode breakdown for one task.
pub fn breakdown(label: &str, spec: &TaskSpec, dev: &DeviceSpec,
                 lv: &Levers) -> Breakdown {
    let c = task_cost(spec, dev, lv);
    let mut phases = Vec::new();
    if c.prefill_wall > 0.0 {
        let mut t = c.prefill_times.clone();
        reconcile_idle(&mut t, c.prefill_wall);
        phases.push(("Prefill".to_string(), t));
    }
    let mut t = c.decode_times.clone();
    reconcile_idle(&mut t, c.decode_wall);
    phases.push(("Decode".to_string(), t));
    Breakdown { label: label.to_string(), phase_times: phases, total: c.total }
}

/// Make the category times sum to the phase wall time by growing/adding
/// the Idle bucket (cost_walk already emits Idle; this re-normalizes
/// after LayerSkip-style wall scaling).
fn reconcile_idle(times: &mut OpTimes, wall: f64) {
    let t = times.total();
    if wall > t {
        times.add("Idle", wall - t);
    }
}

/// Render the figure as a percentage table.
pub fn render(rows: &[Breakdown]) -> String {
    let mut headers = vec!["task/phase", "total(ms)"];
    headers.extend(CATEGORIES);
    let mut table = Table::new(&headers);
    for b in rows {
        for (phase, times) in &b.phase_times {
            let wall: f64 = times.total();
            let mut cells =
                vec![format!("{} [{}]", b.label, phase),
                     format!("{:.2}", wall * 1e3)];
            for cat in CATEGORIES {
                let frac = if wall > 0.0 {
                    times.get(cat) / wall * 100.0
                } else {
                    0.0
                };
                cells.push(format!("{frac:.1}%"));
            }
            table.row(&cells);
        }
    }
    table.render()
}

#[cfg(test)]
mod tests {
    use super::super::configs::{HSTU_14L, LLAMA_7B, SEAMLESS_M4T};
    use super::super::device::A100;
    use super::*;

    #[test]
    fn llama_decode_idle_dominates_eager_baseline() {
        // Obs #2: eager bs=1 decode is dominated by GPU idle time.
        let spec = TaskSpec::Decoder {
            cfg: &LLAMA_7B,
            batch: 1,
            prompt_len: 154,
            decode_steps: 538,
            decodes_per_step: 1,
        };
        let b = breakdown("T-T", &spec, &A100, &Levers::baseline());
        let decode = &b.phase_times.last().unwrap().1;
        let idle_frac = decode.get("Idle") / decode.total();
        assert!(idle_frac > 0.25, "idle {idle_frac}");
    }

    #[test]
    fn hstu_attention_dominates_breakdown() {
        // Obs #3: HSTU is attention-dominated (>90% in the paper).
        let spec = TaskSpec::Hstu { cfg: &HSTU_14L, batch: 32, seq: 4814 };
        let b = breakdown("H-A", &spec, &A100, &Levers::baseline());
        let t = &b.phase_times.last().unwrap().1;
        let busy = t.total() - t.get("Idle");
        assert!(t.get("Attention") / busy > 0.7);
    }

    #[test]
    fn seamless_kv_reorder_visible() {
        // Obs #4: KV reorder is a significant Seamless component.
        let spec = TaskSpec::Seamless {
            cfg: &SEAMLESS_M4T,
            src_len: 493,
            text_steps: 36,
            speech_out: false,
            reorder_fused: false,
            speech_in: true,
        };
        let b = breakdown("S-T", &spec, &A100, &Levers::baseline());
        let t = &b.phase_times.last().unwrap().1;
        assert!(t.get("KV_Reorder") > 0.0);
    }

    #[test]
    fn render_contains_all_categories() {
        let spec = TaskSpec::Hstu { cfg: &HSTU_14L, batch: 1, seq: 1024 };
        let b = breakdown("H-A", &spec, &A100, &Levers::baseline());
        let s = render(&[b]);
        for c in CATEGORIES {
            assert!(s.contains(c), "{c}");
        }
    }
}
