//! Figure 9: roofline analysis — arithmetic intensity vs achieved
//! FLOP/s per workload, baseline vs Sys-Opt.

use super::device::DeviceSpec;
use super::latency::{task_cost, TaskSpec};
use super::levers::Levers;

#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub label: String,
    /// FLOP / byte.
    pub intensity: f64,
    /// Achieved FLOP/s.
    pub perf: f64,
    /// Fraction of the device roofline at this intensity.
    pub roof_frac: f64,
}

/// Device roofline at a given arithmetic intensity.
pub fn roof(dev: &DeviceSpec, intensity: f64) -> f64 {
    (intensity * dev.hbm_bw).min(dev.peak_tensor)
}

/// The knee (intensity where memory- and compute-bound meet).
pub fn knee(dev: &DeviceSpec) -> f64 {
    dev.peak_tensor / dev.hbm_bw
}

/// Compute a roofline point for a task under a lever set.
pub fn point(label: &str, spec: &TaskSpec, dev: &DeviceSpec,
             lv: &Levers) -> RooflinePoint {
    let c = task_cost(spec, dev, lv);
    let intensity = c.flops / c.bytes.max(1.0);
    let perf = c.flops / c.total.max(1e-12);
    RooflinePoint {
        label: label.to_string(),
        intensity,
        perf,
        roof_frac: perf / roof(dev, intensity),
    }
}

#[cfg(test)]
mod tests {
    use super::super::configs::LLAMA_34B;
    use super::super::device::A100;
    use super::*;

    fn spec() -> TaskSpec {
        TaskSpec::Decoder {
            cfg: &LLAMA_34B,
            batch: 1,
            prompt_len: 154,
            decode_steps: 538,
            decodes_per_step: 1,
        }
    }

    #[test]
    fn sys_opt_moves_up_and_right() {
        // §4.4: optimizations increase both arithmetic intensity and
        // achieved performance.
        let base = point("T-T", &spec(), &A100, &Levers::baseline());
        let opt = point("T-T", &spec(), &A100, &Levers::sys_opt());
        assert!(opt.intensity > base.intensity);
        assert!(opt.perf > base.perf);
    }

    #[test]
    fn points_under_the_roof() {
        for lv in [Levers::baseline(), Levers::sys_opt()] {
            let p = point("T-T", &spec(), &A100, &lv);
            assert!(p.roof_frac <= 1.0 + 1e-9, "{}", p.roof_frac);
        }
    }

    #[test]
    fn knee_position_sane() {
        // A100: 156e12 / 2.04e12 ≈ 76 FLOP/B
        let k = knee(&A100);
        assert!(k > 50.0 && k < 100.0, "{k}");
    }

    #[test]
    fn decode_is_left_of_knee() {
        // bs=1 AR decode lives deep in the memory-bound region.
        let p = point("T-T", &spec(), &A100, &Levers::baseline());
        assert!(p.intensity < knee(&A100));
    }
}
