//! Paper-scale model configurations (mirrors python/compile/configs.py
//! PAPER_*). These parameterize the operator walks; they are never
//! executed on CPU.

/// Decoder-only transformer (Code Llama / Chameleon).
#[derive(Debug, Clone, Copy)]
pub struct PaperDecoder {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// GQA: number of KV heads (CodeLlama-34B uses 8; 7B is MHA).
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_hidden: usize,
    pub vocab: usize,
    pub bytes_per_param: usize,
    pub early_exit_layer: usize,
    pub verify_window: usize,
}

impl PaperDecoder {
    /// KV projection width (GQA shrinks it).
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }
    pub fn n_params(&self) -> f64 {
        let d = self.d_model as f64;
        let f = self.ffn_hidden as f64;
        let v = self.vocab as f64;
        let kv = self.kv_dim() as f64;
        let per_layer = 2.0 * d * d + 2.0 * d * kv + 3.0 * d * f + 2.0 * d;
        self.n_layers as f64 * per_layer + 2.0 * v * d + d
    }
    pub fn weight_bytes(&self) -> f64 {
        self.n_params() * self.bytes_per_param as f64
    }
    /// KV bytes per token (fp16 cache, GQA-aware).
    pub fn kv_bytes_per_token(&self) -> f64 {
        (self.n_layers * 2 * self.kv_dim() * 2) as f64
    }
}

pub const LLAMA_7B: PaperDecoder = PaperDecoder {
    name: "CodeLlama-7B",
    n_layers: 32,
    d_model: 4096,
    n_heads: 32,
    n_kv_heads: 32,
    head_dim: 128,
    ffn_hidden: 11008,
    vocab: 32016,
    bytes_per_param: 2,
    early_exit_layer: 8,
    verify_window: 8,
};

pub const LLAMA_34B: PaperDecoder = PaperDecoder {
    name: "CodeLlama-34B",
    n_layers: 48,
    d_model: 8192,
    n_heads: 64,
    n_kv_heads: 8,
    head_dim: 128,
    ffn_hidden: 22016,
    vocab: 32016,
    bytes_per_param: 2,
    early_exit_layer: 12,
    verify_window: 8,
};

pub const CHAMELEON_7B: PaperDecoder = PaperDecoder {
    name: "Chameleon-7B",
    vocab: 65536,
    ..LLAMA_7B
};

pub const CHAMELEON_34B: PaperDecoder = PaperDecoder {
    name: "Chameleon-34B",
    vocab: 65536,
    ..LLAMA_34B
};

/// Seamless M4T-large module dimensions.
#[derive(Debug, Clone, Copy)]
pub struct PaperSeamless {
    pub d_model: usize,
    pub enc_layers: usize,
    pub dec_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub ffn_hidden: usize,
    pub text_vocab: usize,
    pub t2u_layers: usize,
    pub t2u_upsample: usize,
    pub unit_vocab: usize,
    pub voc_channels: usize,
    pub voc_stages: usize,
    pub voc_upsample: usize,
    pub beam: usize,
    pub bytes_per_param: usize,
}

pub const SEAMLESS_M4T: PaperSeamless = PaperSeamless {
    d_model: 1024,
    enc_layers: 24,
    dec_layers: 24,
    n_heads: 16,
    head_dim: 64,
    ffn_hidden: 8192,
    text_vocab: 256_000,
    t2u_layers: 6,
    t2u_upsample: 8,
    unit_vocab: 10_000,
    voc_channels: 512,
    voc_stages: 4,
    voc_upsample: 4,
    beam: 5,
    bytes_per_param: 2,
};

impl PaperSeamless {
    pub fn weight_bytes(&self) -> f64 {
        let d = self.d_model as f64;
        let f = self.ffn_hidden as f64;
        let enc = self.enc_layers as f64 * (4.0 * d * d + 2.0 * d * f + 2.0 * d * d);
        let dec = self.dec_layers as f64 * (8.0 * d * d + 2.0 * d * f);
        let emb = 2.0 * self.text_vocab as f64 * d;
        let t2u = self.t2u_layers as f64 * (4.0 * d * d + 2.0 * d * f)
            + self.unit_vocab as f64 * d;
        let voc = {
            let mut ch = self.voc_channels as f64;
            let mut s = self.unit_vocab as f64 * ch;
            for _ in 0..self.voc_stages {
                s += 7.0 * ch * (ch / 2.0);
                ch /= 2.0;
            }
            s
        };
        (enc + dec + emb + t2u + voc) * self.bytes_per_param as f64
    }
    pub fn kv_bytes_per_token(&self) -> f64 {
        (self.dec_layers * 2 * self.n_heads * self.head_dim * 2) as f64
    }
}

/// HSTU-14L (trillion-parameter-class embeddings excluded — the paper
/// excludes embedding lookup; DLRM serving disaggregates it).
#[derive(Debug, Clone, Copy)]
pub struct PaperHstu {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub full_len_layers: usize,
    pub capped_len: usize,
    pub bytes_per_param: usize,
}

pub const HSTU_14L: PaperHstu = PaperHstu {
    n_layers: 14,
    d_model: 512,
    n_heads: 8,
    head_dim: 64,
    full_len_layers: 3,
    capped_len: 1024,
    bytes_per_param: 2,
};

impl PaperHstu {
    pub fn weight_bytes(&self) -> f64 {
        let d = self.d_model as f64;
        let hs = (self.n_heads * self.head_dim) as f64;
        let per_layer = d * (3.0 * hs + d) + hs * d + 2.0 * d;
        (self.n_layers as f64 * per_layer) * self.bytes_per_param as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_are_in_class() {
        // 7B-class and 34B-class (±15%)
        let p7 = LLAMA_7B.n_params();
        assert!(p7 > 5.5e9 && p7 < 8.0e9, "{p7}");
        let p34 = LLAMA_34B.n_params();
        assert!(p34 > 30e9 && p34 < 37e9, "{p34}");
    }

    #[test]
    fn gqa_shrinks_34b_kv() {
        // 34B uses GQA (8 kv heads): its per-token KV is *smaller* than
        // the MHA 7B despite having more layers.
        assert!(LLAMA_34B.kv_bytes_per_token() < LLAMA_7B.kv_bytes_per_token());
    }

    #[test]
    fn seamless_weight_bytes_reasonable() {
        // M4T-large ≈ 2.3B params ⇒ ~4.6 GB at fp16 (±50% for the
        // simplified accounting here).
        let b = SEAMLESS_M4T.weight_bytes();
        assert!(b > 2e9 && b < 8e9, "{b}");
    }
}
