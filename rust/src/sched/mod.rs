//! Unified tick scheduler + step-executor layer.
//!
//! Before this layer existed, every serving path hand-rolled its own
//! schedule→dispatch→sample→bookkeep loop: the batched worker in
//! `coordinator::server`, the bs=1 `decoder_loop`, `eager`, and
//! `layerskip`. That made per-tick policy (prefill/decode interference,
//! chunked prefill, capacity-aware admission) impossible to implement
//! once. This module centralizes it:
//!
//! * [`plan`] — the [`Scheduler`]: turns queue state + the kvpool
//!   [`CapacityView`](crate::kvpool::CapacityView) into an explicit
//!   per-tick [`TickPlan`] — the decode set plus prefill *chunks* under
//!   a token budget, with page-aware chunk admission. Whole-prompt mode
//!   (`chunk = 0`) reproduces the continuous batcher's admission
//!   exactly; chunked mode splits long prompts into budget-sized
//!   chunks interleaved with decode ticks, which is the paper's
//!   prefill/decode-interference lever.
//! * [`exec`] — the [`StepExecutor`] trait (`plan_dims` /
//!   `prefill_chunk` / `decode_step` / `verify` / `reorder_slots`
//!   hooks) and the generic drivers: [`exec::generate`] (one-request
//!   decode loop shared by the compiled-graph and eager executors),
//!   [`exec::generate_speculative`] (the LayerSkip draft/verify
//!   round), and [`exec::generate_beam`] (length-normalized beam
//!   search whose reorder is a kvpool block-table fork + prune, not a
//!   KV copy). The batched worker's `run_tick` in
//!   `coordinator::server` consumes a [`TickPlan`] against the same
//!   trait.
//!
//! ```text
//!            requests ──► Batcher queue
//!                              │
//!                              ▼
//!   CapacityView ───► Scheduler::plan ───► TickPlan
//!   (kvpool pages                            │
//!    + batch slots)                          ▼
//!                              run_tick(plan, executor)
//!                              │  prefill_chunk / decode_step
//!                              ▼
//!   StepExecutor: batched graph │ bs=1 graph │ eager │ layerskip
//!                 │ seamless beam │ hstu one-shot
//! ```

pub mod exec;
pub mod plan;

pub use exec::{generate, generate_beam, generate_speculative,
               log_softmax, top_n, BeamConfig, BeamResult, ExecDims,
               SlotFeed, SlotStateError, StepExecutor};
pub use plan::{PlannedChunk, SchedConfig, Scheduler, TickPlan};
