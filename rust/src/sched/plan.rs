//! The tick planner: queue + capacity view → explicit [`TickPlan`].
//!
//! The `Scheduler` owns the FCFS [`Batcher`] queue and the in-flight
//! request state machine (Queued → Prefilling → Decoding). Every
//! scheduler tick it emits a `TickPlan` that the worker executes
//! against a [`StepExecutor`](super::exec::StepExecutor):
//!
//! * **Whole-prompt mode** (`chunk == 0`): admission delegates to
//!   [`Batcher::tick`] — byte-for-byte the continuous batcher's policy
//!   (FCFS, per-tick prefill token budget, oversize-alone exception,
//!   page-aware admission) — and each admitted request becomes a single
//!   full-prompt chunk.
//! * **Chunked mode** (`chunk > 0`): at most `chunk` *new* prompt
//!   tokens are planned per tick, FCFS across in-flight prefills first
//!   and then fresh admissions, each chunk gated on the pages it needs
//!   (block-rounded, plus one position of decode headroom on the final
//!   chunk). Long prompts therefore prefill across several ticks with
//!   decode steps interleaved — the chunked-prefill lever that bounds
//!   decode-tick stalls behind big admissions.
//!
//! Planner invariants (property-tested below): planned chunk tokens
//! never exceed the budget, a chunk is only planned when the capacity
//! view covers its pages, and the decode set and the chunked request
//! set are disjoint.

use crate::coordinator::batcher::{Batcher, QueuedRequest};
use crate::kvpool::{pages_for, CapacityView};

/// Scheduler knobs (both come from `RouterConfig` / the CLI).
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedConfig {
    /// Whole-prompt mode: max prompt tokens admitted per tick
    /// (0 = unlimited). Ignored when `chunk > 0`.
    pub prefill_budget: usize,
    /// Chunked prefill: max new prompt tokens fed per tick
    /// (0 = whole-prompt admission).
    pub chunk: usize,
}

/// One prompt chunk to feed this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedChunk {
    pub request: u64,
    /// Token offset into the request's prefill prefix.
    pub start: usize,
    /// Tokens to feed this tick (> 0).
    pub len: usize,
    /// First chunk: the worker claims a slot + the chunk's pages.
    pub is_first: bool,
    /// Final chunk: completing it yields the first-token logits.
    pub is_last: bool,
}

/// The explicit per-tick schedule.
#[derive(Debug, Clone, Default)]
pub struct TickPlan {
    /// Prompt chunks to feed, FCFS (in-flight prefills before fresh
    /// admissions; a fresh admission's first chunk appears here too).
    pub chunks: Vec<PlannedChunk>,
    /// Requests popped from the queue this tick (their `is_first`
    /// chunk is in `chunks`); the worker requeues these on a failed
    /// slot/page claim.
    pub admitted: Vec<QueuedRequest>,
    /// Requests expected to take a decode step this tick. Advisory:
    /// the tick driver derives the live decode set from slot state
    /// (which can shrink mid-tick via preemption); this field exists
    /// for planning-level invariants (decode ∩ chunks = ∅) and
    /// deviceless consumers.
    pub decode: Vec<u64>,
    /// Whether a decode step should run (advisory, see `decode`).
    pub run_decode: bool,
    /// Admission was (partially) blocked on the KV page budget — feeds
    /// the `KvCapacity` idle-attribution bucket.
    pub blocked_on_capacity: bool,
    /// Total planned chunk tokens (≤ the tick budget in chunked mode).
    pub prefill_tokens: usize,
}

#[derive(Debug, Clone, Copy)]
struct PrefillProgress {
    request: u64,
    done: usize,
    total: usize,
}

/// The unified tick scheduler.
#[derive(Debug)]
pub struct Scheduler {
    batcher: Batcher,
    cfg: SchedConfig,
    /// Mid-prefill requests in admission (FCFS) order.
    prefilling: Vec<PrefillProgress>,
    /// Requests decoding (prompt fully prefilled), admission order.
    decoding: Vec<u64>,
}

impl Scheduler {
    pub fn new(cfg: SchedConfig) -> Self {
        Scheduler {
            batcher: Batcher::new(cfg.prefill_budget),
            cfg,
            prefilling: Vec::new(),
            decoding: Vec::new(),
        }
    }

    /// Queue a new request (FCFS tail).
    pub fn enqueue(&mut self, q: QueuedRequest) {
        self.batcher.push(q);
    }

    /// Attach the live-metrics plane to the admission queue
    /// ([`Batcher::attach_live`]): replica-labeled enqueue/admission
    /// counters. Pure observation.
    pub fn attach_live(&mut self,
                       live: &crate::telemetry::live::LiveMetrics,
                       replica: usize) {
        self.batcher.attach_live(live, replica);
    }

    /// Requests waiting in the queue (not in flight).
    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    /// Requests mid-prefill or decoding.
    pub fn in_flight(&self) -> usize {
        self.prefilling.len() + self.decoding.len()
    }

    /// Compute this tick's plan against the capacity view.
    pub fn plan(&mut self, cap: &CapacityView) -> TickPlan {
        if self.cfg.chunk == 0 {
            self.plan_whole(cap)
        } else {
            self.plan_chunked(cap)
        }
    }

    /// Whole-prompt admission: exactly the continuous batcher's policy.
    fn plan_whole(&mut self, cap: &CapacityView) -> TickPlan {
        let adm = self.batcher.tick(cap);
        let mut plan = TickPlan {
            decode: self.decoding.clone(),
            run_decode: adm.run_decode,
            blocked_on_capacity: adm.blocked_on_capacity,
            ..TickPlan::default()
        };
        for q in adm.admit {
            let total = q.prompt_len.max(1);
            self.prefilling.push(PrefillProgress {
                request: q.id,
                done: 0,
                total,
            });
            plan.chunks.push(PlannedChunk {
                request: q.id,
                start: 0,
                len: total,
                is_first: true,
                is_last: true,
            });
            plan.prefill_tokens += total;
            plan.admitted.push(q);
        }
        plan
    }

    /// Chunked admission: at most `chunk` new prompt tokens per tick,
    /// in-flight prefills first (FCFS), then fresh admissions, every
    /// chunk gated on the pages it will claim.
    fn plan_chunked(&mut self, cap: &CapacityView) -> TickPlan {
        let mut plan = TickPlan {
            decode: self.decoding.clone(),
            ..TickPlan::default()
        };
        let mut remaining = self.cfg.chunk;
        let mut pages_left = cap
            .pages
            .as_ref()
            .map(|p| p.available_pages.saturating_sub(p.reserved_growth));

        // In-flight prefills continue first (no head-of-line bypass:
        // the first blocked chunk stops all further prefill planning).
        for p in &self.prefilling {
            if remaining == 0 {
                break;
            }
            let rest = p.total.saturating_sub(p.done);
            if rest == 0 {
                continue;
            }
            let len = rest.min(remaining);
            let is_last = p.done + len == p.total;
            let need = chunk_pages(cap, p.done, len, is_last);
            if let Some(left) = pages_left.as_mut() {
                if need > *left {
                    plan.blocked_on_capacity = true;
                    break;
                }
                *left -= need;
            }
            plan.chunks.push(PlannedChunk {
                request: p.request,
                start: p.done,
                len,
                is_first: p.done == 0,
                is_last,
            });
            plan.prefill_tokens += len;
            remaining -= len;
        }

        // Fresh admissions with whatever budget and slots remain.
        let mut free = cap.free_slots;
        while free > 0 && remaining > 0 && !plan.blocked_on_capacity {
            let Some(front) = self.batcher.front() else { break };
            let total = front.prompt_len.max(1);
            let len = total.min(remaining);
            let is_last = len == total;
            let need = chunk_pages(cap, 0, len, is_last);
            if let Some(left) = pages_left.as_mut() {
                if need > *left {
                    plan.blocked_on_capacity = true;
                    break;
                }
                *left -= need;
            }
            let q = self.batcher.pop_front().expect("front exists");
            self.prefilling.push(PrefillProgress {
                request: q.id,
                done: 0,
                total,
            });
            plan.chunks.push(PlannedChunk {
                request: q.id,
                start: 0,
                len,
                is_first: true,
                is_last,
            });
            plan.prefill_tokens += len;
            plan.admitted.push(q);
            remaining -= len;
            free -= 1;
        }

        plan.run_decode = !plan.decode.is_empty();
        plan
    }

    /// The worker fed `fed` chunk tokens for `request`; a completed
    /// prompt moves the request to the decode set.
    pub fn chunk_committed(&mut self, request: u64, fed: usize) {
        if let Some(i) =
            self.prefilling.iter().position(|p| p.request == request)
        {
            self.prefilling[i].done += fed;
            if self.prefilling[i].done >= self.prefilling[i].total {
                self.prefilling.remove(i);
                self.decoding.push(request);
            }
        }
    }

    /// Requeue one request at the queue head (preemption victim or a
    /// capacity-raced admission), dropping its in-flight state.
    pub fn requeue_front(&mut self, q: QueuedRequest) {
        self.forget(q.id);
        self.batcher.push_front(q);
    }

    /// Requeue a group at the head preserving `qs` order (see
    /// [`Batcher::requeue_all`] — per-item `push_front` would reverse
    /// the group and break FCFS).
    pub fn requeue_all(&mut self, qs: Vec<QueuedRequest>) {
        for q in &qs {
            self.forget(q.id);
        }
        self.batcher.requeue_all(qs);
    }

    /// A request completed (response sent).
    pub fn finished(&mut self, request: u64) {
        self.forget(request);
    }

    /// A request failed or was shed; drop all scheduler state for it.
    pub fn drop_request(&mut self, request: u64) {
        self.forget(request);
    }

    /// Shed the queue head (a request that can never be admitted).
    pub fn shed_front(&mut self) -> Option<QueuedRequest> {
        self.batcher.pop_front()
    }

    /// Head-of-line mid-prefill request — the one whose blocked chunk
    /// stalls all chunked planning (FCFS, no bypass). The worker sheds
    /// it when its remaining chunks can never be granted pages and no
    /// decode work exists to free any.
    pub fn head_prefilling(&self) -> Option<u64> {
        self.prefilling.first().map(|p| p.request)
    }

    fn forget(&mut self, request: u64) {
        self.prefilling.retain(|p| p.request != request);
        self.decoding.retain(|&r| r != request);
    }
}

/// New pages a chunk `[start, start+len)` claims, block-rounded, with
/// one extra position of decode headroom on the final chunk (mirrors
/// the whole-prompt `pages_needed(prompt_len) = pages(prompt_len + 1)`
/// admission rule). Worst case: prefix sharing can only reduce it.
pub fn chunk_pages(cap: &CapacityView, start: usize, len: usize,
                   is_last: bool) -> usize {
    match &cap.pages {
        Some(p) => {
            let end = start + len + usize::from(is_last);
            pages_for(end, p.page_size)
                .saturating_sub(pages_for(start, p.page_size))
        }
        None => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::PageBudget;
    use crate::substrate::prop::prop_check;
    use crate::substrate::rng::Rng;

    fn rq(id: u64, plen: usize) -> QueuedRequest {
        QueuedRequest { id, prompt_len: plen, max_new_tokens: 8 }
    }

    fn dense(free: usize, live: usize) -> CapacityView {
        CapacityView::dense(free, live)
    }

    #[test]
    fn whole_mode_matches_batcher_admission() {
        let mut s = Scheduler::new(SchedConfig {
            prefill_budget: 100,
            chunk: 0,
        });
        s.enqueue(rq(0, 60));
        s.enqueue(rq(1, 60));
        s.enqueue(rq(2, 30));
        let plan = s.plan(&dense(3, 0));
        // Same as Batcher::tick: 60 fits, the next 60 exceeds, FCFS
        // stops (no head-of-line bypass).
        assert_eq!(plan.admitted.len(), 1);
        assert_eq!(plan.admitted[0].id, 0);
        assert_eq!(plan.chunks.len(), 1);
        let c = plan.chunks[0];
        assert!(c.is_first && c.is_last);
        assert_eq!((c.start, c.len), (0, 60));
        assert_eq!(plan.prefill_tokens, 60);
        assert_eq!(s.pending(), 2);
        assert_eq!(s.in_flight(), 1);
    }

    #[test]
    fn whole_mode_chunk_commit_moves_to_decode_set() {
        let mut s = Scheduler::new(SchedConfig::default());
        s.enqueue(rq(7, 20));
        let plan = s.plan(&dense(2, 0));
        assert_eq!(plan.chunks.len(), 1);
        assert!(plan.decode.is_empty());
        s.chunk_committed(7, 20);
        let plan2 = s.plan(&dense(1, 1));
        assert_eq!(plan2.decode, vec![7]);
        assert!(plan2.run_decode);
        s.finished(7);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn chunked_mode_splits_long_prompt_across_ticks() {
        let mut s = Scheduler::new(SchedConfig {
            prefill_budget: 0,
            chunk: 32,
        });
        s.enqueue(rq(1, 100));
        // Tick 1: first chunk of 32.
        let p1 = s.plan(&dense(4, 0));
        assert_eq!(p1.chunks.len(), 1);
        assert_eq!((p1.chunks[0].start, p1.chunks[0].len), (0, 32));
        assert!(p1.chunks[0].is_first && !p1.chunks[0].is_last);
        assert_eq!(p1.admitted.len(), 1);
        s.chunk_committed(1, 32);
        // Ticks 2–3: continuations; tick 4: the 4-token tail is last.
        for (tick, (start, len)) in
            [(2usize, (32usize, 32usize)), (3, (64, 32))]
        {
            let p = s.plan(&dense(3, 1));
            assert_eq!(p.chunks.len(), 1, "tick {tick}");
            assert_eq!((p.chunks[0].start, p.chunks[0].len), (start, len));
            assert!(!p.chunks[0].is_last);
            assert!(p.admitted.is_empty(), "no re-admission mid-prefill");
            s.chunk_committed(1, len);
        }
        let p4 = s.plan(&dense(3, 1));
        assert_eq!((p4.chunks[0].start, p4.chunks[0].len), (96, 4));
        assert!(p4.chunks[0].is_last);
        s.chunk_committed(1, 4);
        assert_eq!(s.in_flight(), 1, "now decoding");
        let p5 = s.plan(&dense(3, 1));
        assert!(p5.chunks.is_empty());
        assert_eq!(p5.decode, vec![1]);
    }

    #[test]
    fn chunked_mode_budget_is_shared_fcfs() {
        let mut s = Scheduler::new(SchedConfig {
            prefill_budget: 0,
            chunk: 40,
        });
        s.enqueue(rq(1, 30));
        s.enqueue(rq(2, 30));
        s.enqueue(rq(3, 5));
        let p = s.plan(&dense(4, 0));
        // 30 to request 1, the remaining 10 start request 2; request 3
        // must not jump the queue.
        assert_eq!(p.chunks.len(), 2);
        assert_eq!(p.chunks[0].request, 1);
        assert!(p.chunks[0].is_last);
        assert_eq!(p.chunks[1].request, 2);
        assert_eq!((p.chunks[1].start, p.chunks[1].len), (0, 10));
        assert!(!p.chunks[1].is_last);
        assert_eq!(p.prefill_tokens, 40);
        assert_eq!(s.pending(), 1);
    }

    #[test]
    fn chunked_mode_gates_chunks_on_pages() {
        let cap = CapacityView {
            free_slots: 2,
            live_slots: 1,
            pages: Some(PageBudget {
                page_size: 4,
                available_pages: 3,
                reserved_growth: 1,
                shards: 1,
            }),
        };
        let mut s = Scheduler::new(SchedConfig {
            prefill_budget: 0,
            chunk: 64,
        });
        // 2 grantable pages = 8 positions; a 30-token first chunk (+1
        // headroom) needs 8 pages → blocked, stays queued.
        s.enqueue(rq(1, 30));
        let p = s.plan(&cap);
        assert!(p.chunks.is_empty());
        assert!(p.blocked_on_capacity);
        assert_eq!(s.pending(), 1);
        // A 6-token prompt (2 pages with headroom) fits.
        let mut s = Scheduler::new(SchedConfig {
            prefill_budget: 0,
            chunk: 64,
        });
        s.enqueue(rq(2, 6));
        let p = s.plan(&cap);
        assert_eq!(p.chunks.len(), 1);
        assert!(!p.blocked_on_capacity);
    }

    /// Tentpole: chunked-plan page gating over a *sharded* pool. The
    /// headroom the planner gates chunks against is the per-shard
    /// headroom summed (pages spill across arenas, so the sum is
    /// exactly grantable), and a plan over a sharded view is identical
    /// to one over a monolithic view with the same aggregate.
    #[test]
    fn chunked_gating_over_sharded_view_matches_aggregate_headroom() {
        use crate::kvpool::KvPool;
        let sharded = KvPool::with_shards(8, 4, 64, 2);
        let cap = sharded.capacity_view(2, 0);
        let b = cap.pages.unwrap();
        assert_eq!(b.shards, 2);
        assert_eq!(
            b.available_pages,
            sharded
                .shard_views()
                .iter()
                .map(|v| v.headroom())
                .sum::<usize>(),
            "gated headroom is the per-shard sum"
        );
        let plan_under = |cap: &CapacityView| {
            let mut s = Scheduler::new(SchedConfig {
                prefill_budget: 0,
                chunk: 64,
            });
            s.enqueue(rq(1, 20)); // 20+1 tokens → 6 of the 8 pages
            s.enqueue(rq(2, 20)); // 6 more pages > the 2 left → blocked
            s.plan(cap)
        };
        let p = plan_under(&cap);
        assert_eq!(p.chunks.len(), 1);
        assert_eq!(p.chunks[0].request, 1);
        assert!(p.blocked_on_capacity);
        // Same aggregate, one arena: byte-identical plan.
        let mono = KvPool::new(8, 4, 64);
        let q = plan_under(&mono.capacity_view(2, 0));
        assert_eq!(p.chunks, q.chunks);
        assert_eq!(p.blocked_on_capacity, q.blocked_on_capacity);
        assert_eq!(p.prefill_tokens, q.prefill_tokens);
    }

    #[test]
    fn requeue_front_restores_queue_position_and_state() {
        let mut s = Scheduler::new(SchedConfig {
            prefill_budget: 0,
            chunk: 16,
        });
        s.enqueue(rq(1, 40));
        s.enqueue(rq(2, 8));
        let p = s.plan(&dense(4, 0));
        assert_eq!(p.chunks[0].request, 1);
        s.chunk_committed(1, 16);
        // Request 1 is preempted mid-prefill: requeued at the front,
        // in-flight state dropped, and it restarts from chunk 0.
        // (Request 2 never got budget, so it is still queued.)
        s.requeue_front(rq(1, 40));
        assert_eq!(s.in_flight(), 0, "in-flight state dropped");
        assert_eq!(s.pending(), 2);
        let p2 = s.plan(&dense(4, 1));
        let first = p2.chunks.iter().find(|c| c.request == 1).unwrap();
        assert_eq!(first.start, 0, "restart from the beginning");
        assert!(first.is_first);
    }

    #[test]
    fn chunk_pages_rounds_blocks_and_adds_decode_headroom() {
        let cap = CapacityView {
            free_slots: 1,
            live_slots: 0,
            pages: Some(PageBudget {
                page_size: 4,
                available_pages: 100,
                reserved_growth: 0,
                shards: 1,
            }),
        };
        // [0, 5) not last: 2 pages. Continuing [5, 8): still page 2 —
        // 0 new pages. Final chunk [8, 9): 1 token + headroom → 1 page.
        assert_eq!(chunk_pages(&cap, 0, 5, false), 2);
        assert_eq!(chunk_pages(&cap, 5, 3, false), 0);
        assert_eq!(chunk_pages(&cap, 8, 1, true), 1);
        // Dense view: pages are unmetered.
        assert_eq!(chunk_pages(&dense(1, 0), 0, 100, true), 0);
    }

    /// Satellite property test: every `TickPlan` (1) respects the
    /// chunk token budget, (2) never plans a chunk whose pages the
    /// capacity view cannot cover, and (3) keeps the decode and
    /// prefill-chunk request sets disjoint — across random workloads,
    /// budgets, and pool pressure, with random commit/finish churn.
    #[test]
    fn prop_tick_plans_respect_budget_pages_and_disjointness() {
        prop_check(
            120,
            2024,
            |r: &mut Rng| {
                let n = r.usize(1, 12);
                let lens: Vec<usize> =
                    (0..n).map(|_| r.usize(1, 120)).collect();
                let chunk = r.usize(1, 48);
                let slots = r.usize(1, 6);
                let pages = r.usize(4, 64);
                let page_size = r.usize(1, 8);
                (lens, ((chunk, slots), (pages, page_size)))
            },
            |(lens, ((chunk, slots), (pages, page_size)))| {
                // Shrinking may propose degenerate knobs; the property
                // is only about chunked-mode plans.
                if *chunk == 0 || *slots == 0 || *pages == 0
                    || *page_size == 0
                {
                    return Ok(());
                }
                let mut s = Scheduler::new(SchedConfig {
                    prefill_budget: 0,
                    chunk: *chunk,
                });
                for (i, &l) in lens.iter().enumerate() {
                    s.enqueue(rq(i as u64 + 1, l));
                }
                // Simulated pool: per-request fed token counts drive
                // the page accounting the view reports.
                let mut fed: std::collections::HashMap<u64, usize> =
                    std::collections::HashMap::new();
                let mut decoding: Vec<u64> = Vec::new();
                let mut churn = Rng::new(*chunk as u64 ^ 0xfeed);
                for _tick in 0..200 {
                    if s.pending() == 0 && s.in_flight() == 0 {
                        break;
                    }
                    let used: usize = fed
                        .values()
                        .map(|&f| pages_for(f, *page_size))
                        .sum();
                    let cap = CapacityView {
                        free_slots: slots.saturating_sub(fed.len()),
                        live_slots: fed.len(),
                        pages: Some(PageBudget {
                            page_size: *page_size,
                            available_pages: pages.saturating_sub(used),
                            reserved_growth: fed.len(),
                            shards: 1,
                        }),
                    };
                    let plan = s.plan(&cap);

                    // (1) budget respected.
                    let total: usize =
                        plan.chunks.iter().map(|c| c.len).sum();
                    if total != plan.prefill_tokens {
                        return Err("prefill_tokens mismatch".into());
                    }
                    if total > *chunk {
                        return Err(format!(
                            "chunk tokens {total} > budget {chunk}"
                        ));
                    }
                    // (2) pages covered (sum over planned chunks).
                    let need: usize = plan
                        .chunks
                        .iter()
                        .map(|c| chunk_pages(&cap, c.start, c.len,
                                             c.is_last))
                        .sum();
                    let grantable = pages
                        .saturating_sub(used)
                        .saturating_sub(fed.len());
                    if need > grantable {
                        return Err(format!(
                            "planned {need} pages > grantable {grantable}"
                        ));
                    }
                    // (3) decode/prefill disjoint; no duplicate chunks.
                    for c in &plan.chunks {
                        if plan.decode.contains(&c.request) {
                            return Err(format!(
                                "request {} both decodes and prefills",
                                c.request
                            ));
                        }
                        if c.len == 0 {
                            return Err("empty chunk planned".into());
                        }
                    }
                    let mut ids: Vec<u64> =
                        plan.chunks.iter().map(|c| c.request).collect();
                    ids.sort_unstable();
                    ids.dedup();
                    if ids.len() != plan.chunks.len() {
                        return Err("two chunks for one request".into());
                    }
                    // New admissions may not exceed free slots.
                    if plan.admitted.len() > cap.free_slots {
                        return Err("admitted beyond free slots".into());
                    }

                    // Commit the plan into the simulated pool.
                    for c in &plan.chunks {
                        *fed.entry(c.request).or_insert(0) += c.len;
                        s.chunk_committed(c.request, c.len);
                        if c.is_last {
                            decoding.push(c.request);
                        }
                    }
                    // Random churn: finish some decoding request.
                    if !decoding.is_empty() && churn.usize(0, 3) == 0 {
                        let id =
                            decoding.remove(churn.usize(0, decoding.len()));
                        fed.remove(&id);
                        s.finished(id);
                    }
                }
                // Drain: everything either finished or still tracked.
                Ok(())
            },
        );
    }
}
