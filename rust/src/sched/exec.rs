//! The step-executor abstraction and the generic decode drivers.
//!
//! A [`StepExecutor`] is what one serving engine looks like to the
//! scheduler: it can feed prompt chunks (`prefill_chunk`), take one
//! decode step for a set of slots (`decode_step`), and — for
//! self-speculative engines — verify a drafted window in one pass
//! (`verify`). Six implementations exist:
//!
//! | executor                         | lives in                  |
//! |----------------------------------|---------------------------|
//! | `BatchedExecutor` (compiled graph, B slots) | `coordinator::server` |
//! | `GraphExecutor` (compiled graph, bs=1)      | `coordinator::decoder_loop` |
//! | `EagerExecutor` (per-op dispatch, bs=1)     | `coordinator::eager` |
//! | `LayerSkipExecutor` (draft/verify, bs=1)    | `coordinator::layerskip` |
//! | `SeamlessExecutor` (beam decoder, B beams)  | `coordinator::seamless_pipe` |
//! | `HstuExecutor` (one-shot scoring, prefill-only) | `coordinator::hstu_loop` |
//!
//! The drivers here replace the hand-rolled generate loops:
//! [`generate`] runs the shared bs=1 prefill→sample→decode loop (the
//! compiled-graph and eager paths differ only in how their executor
//! consumes the prompt), [`generate_speculative`] runs the LayerSkip
//! draft/verify round against the `decode_step` (draft) and `verify`
//! hooks, and [`generate_beam`] runs length-normalized beam search
//! where every hypothesis is a kvpool block table — a beam reorder is
//! fork + prune plus one [`StepExecutor::reorder_slots`] device
//! gather, not a KV copy (the paper's Obs #4 fix expressed in pages).
//! The batched worker's tick driver consumes a
//! [`TickPlan`](super::plan::TickPlan) against the same trait in
//! `coordinator::server::run_tick`. A prefill-only executor (HSTU's
//! one-shot scoring pass) is simply [`generate`] with `max_new == 0`:
//! zero decode ticks, the whole request is its prompt.

use std::cmp::Ordering;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::decoder_loop::GenResult;
use crate::coordinator::request::SamplingParams;
use crate::coordinator::sampling;
use crate::kvpool::{pages_for, KvPool, DEFAULT_PAGE_SIZE};
use crate::models::tokenizer;
use crate::substrate::rng::Rng;
use crate::telemetry::tracer::{Cat, WorkerTracer};

/// Static dimensions the planner and drivers size their loops by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecDims {
    /// Decode slots the executor steps at once (1 for bs=1 engines).
    pub batch: usize,
    /// Sequence capacity per slot.
    pub max_seq: usize,
    /// Logits row width.
    pub vocab: usize,
}

/// One slot's input to a decode step: feed `token` at `pos`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotFeed {
    pub slot: usize,
    pub token: i32,
    pub pos: usize,
}

/// Structured slot-state errors for the batched worker: a live slot
/// whose bookkeeping went missing is surfaced through the request's
/// `Response` channel (or logged) instead of panicking the worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotStateError {
    /// A slot the plan expected to decode has no `SlotJob`.
    MissingJob { slot: usize, request: u64 },
    /// A planned chunk's request has no prefill state.
    MissingPrefill { request: u64 },
}

impl std::fmt::Display for SlotStateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SlotStateError::MissingJob { slot, request } => write!(
                f,
                "slot {slot} is live for request {request} but holds no \
                 decode job"
            ),
            SlotStateError::MissingPrefill { request } => write!(
                f,
                "request {request} was planned a prefill chunk but has \
                 no prefill state"
            ),
        }
    }
}

impl std::error::Error for SlotStateError {}

/// One serving engine, as seen by the scheduler.
///
/// Implement `plan_dims`, `prefill_chunk`, and `decode_step` and any
/// of the generic drivers ([`generate`], [`generate_speculative`],
/// [`generate_beam`], `coordinator::server::run_tick`) can serve the
/// engine; the optional hooks (`verify`, `reorder_slots`) opt into
/// self-speculative and beam-search scheduling.
///
/// # Examples
///
/// A minimal greedy engine the [`generate`] driver can run — the
/// "model" predicts token 2 after the prompt, then EOS (token 1):
///
/// ```
/// use anyhow::Result;
/// use mmserve::coordinator::request::SamplingParams;
/// use mmserve::sched::{generate, ExecDims, SlotFeed, StepExecutor};
///
/// struct Scripted;
///
/// impl StepExecutor for Scripted {
///     fn plan_dims(&self) -> ExecDims {
///         ExecDims { batch: 1, max_seq: 32, vocab: 4 }
///     }
///     fn prefill_chunk(&mut self, _slot: usize, _tokens: &[i32],
///                      _start: usize, is_last: bool)
///                      -> Result<Option<Vec<f32>>> {
///         // Logits for the last prompt position: predict token 2.
///         Ok(is_last.then(|| vec![0.0, 0.0, 1.0, 0.0]))
///     }
///     fn decode_step(&mut self, _feeds: &[SlotFeed])
///                    -> Result<Vec<f32>> {
///         // After any decode token: predict EOS.
///         Ok(vec![0.0, 1.0, 0.0, 0.0])
///     }
/// }
///
/// let mut exec = Scripted;
/// let r = generate(&mut exec, None, &[3, 3], 8,
///                  &SamplingParams::greedy())?;
/// assert_eq!(r.tokens, vec![2, 1]); // scripted token, then EOS
/// # Ok::<(), anyhow::Error>(())
/// ```
pub trait StepExecutor {
    /// Batch width, sequence capacity, and vocab size.
    fn plan_dims(&self) -> ExecDims;

    /// Span name for one decode step (telemetry).
    fn step_span_name(&self) -> &'static str {
        "decode_step"
    }

    /// Feed prompt tokens `[start, start+len)` for `slot`. Returns the
    /// final position's logits when `is_last` completed the prompt;
    /// `Ok(None)` when the prompt is not finished — either because
    /// more chunks follow, or because the executor capped early (e.g.
    /// the prompt reaches the sequence capacity), in which case the
    /// driver generates nothing.
    fn prefill_chunk(&mut self, slot: usize, tokens: &[i32], start: usize,
                     is_last: bool) -> Result<Option<Vec<f32>>>;

    /// One decode step: feed each slot its token at its position,
    /// return logits `[batch × vocab]`. For a self-speculative
    /// executor this is the *draft* step.
    fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>>;

    /// Verify a drafted window of `verify_window()` tokens starting at
    /// `start` in one full-model pass; returns logits
    /// `[window × vocab]`. Only self-speculative executors implement
    /// this.
    fn verify(&mut self, _slot: usize, _window: &[i32], _start: usize)
              -> Result<Vec<f32>> {
        bail!("this executor has no verify stage")
    }

    /// Draft window size for [`generate_speculative`] (0 = not a
    /// speculative executor).
    fn verify_window(&self) -> usize {
        0
    }

    /// Permute per-slot device KV after a beam reorder: new slot `b`
    /// continues from old slot `src[b]`. By the time this runs the
    /// paging layer has already re-pointed the hypotheses' block
    /// tables (fork + prune, no page copied); this hook is only the
    /// device-side gather a dense decoder cache needs. Only beam
    /// executors implement it.
    fn reorder_slots(&mut self, _src: &[i32]) -> Result<()> {
        bail!("this executor has no beam reorder")
    }
}

/// The shared bs=1 generation loop: chunked prompt feed (the executor
/// decides how it consumes the chunk — one bucketed prefill for the
/// compiled graph, token-by-token for eager), then sample→decode with
/// the position bookkeeping running through a solo kvpool block table.
pub fn generate(exec: &mut impl StepExecutor, tele: Option<&WorkerTracer>,
                prompt: &[i32], max_new: usize, sp: &SamplingParams)
                -> Result<GenResult> {
    let t0 = Instant::now();
    let dims = exec.plan_dims();
    let _tick_scope = tele.map(|t| t.tick_scope());
    let mut rng = Rng::new(sp.seed);
    let prefill_span = tele.map(|t| t.span(Cat::Prefill, "prefill"));
    let first_logits = exec.prefill_chunk(0, prompt, 0, true)?;
    drop(prefill_span);
    let ttft = t0.elapsed().as_secs_f64();
    let mut pool = KvPool::solo(dims.max_seq);
    let table_len = prompt.len().min(dims.max_seq - 1);
    pool.alloc(0, &prompt[..table_len])?;
    let mut pos = prompt.len();
    let mut out = Vec::with_capacity(max_new);
    // `None` means the executor capped before finishing the prompt
    // (eager stops feeding at the sequence capacity): emit nothing.
    if let Some(mut logits) = first_logits {
        for _ in 0..max_new {
            if let Some(t) = tele {
                t.next_tick();
            }
            let _step_span =
                tele.map(|t| t.span(Cat::Decode, exec.step_span_name()));
            let tok = {
                let _s = tele.map(|t| t.span(Cat::Sample, "sample"));
                sampling::sample(&logits, sp, &mut rng)
            };
            out.push(tok);
            if tok == tokenizer::EOS || pos + 1 >= dims.max_seq {
                break;
            }
            if out.len() >= max_new {
                break;
            }
            logits =
                exec.decode_step(&[SlotFeed { slot: 0, token: tok, pos }])?;
            pos = pool.advance(0, tok)?;
        }
    }
    pool.release(0)?;
    debug_assert!(pool.check_invariants().is_ok());
    Ok(GenResult {
        prompt_tokens: prompt.len(),
        decode_steps: out.len(),
        tokens: out,
        ttft,
        e2e: t0.elapsed().as_secs_f64(),
        accepted_drafts: 0,
        draft_rounds: 0,
    })
}

/// The self-speculative round (LayerSkip, §4.3): draft
/// `verify_window() − 1` cheap tokens through `decode_step`, verify the
/// whole window in one `verify` pass, accept the longest matching
/// prefix greedily, emit a bonus token from the verify logits, and
/// rewind the block table to the accepted position.
pub fn generate_speculative(exec: &mut impl StepExecutor,
                            tele: Option<&WorkerTracer>, prompt: &[i32],
                            max_new: usize, sp: &SamplingParams)
                            -> Result<GenResult> {
    let t0 = Instant::now();
    let dims = exec.plan_dims();
    let k_window = exec.verify_window();
    if k_window < 2 {
        bail!("speculative decoding needs a verify window ≥ 2");
    }
    let mut rng = Rng::new(sp.seed);
    let _tick_scope = tele.map(|t| t.tick_scope());
    let prefill_span = tele.map(|t| t.span(Cat::Prefill, "prefill"));
    let logits = exec
        .prefill_chunk(0, prompt, 0, true)?
        .context("speculative prefill must produce logits")?;
    drop(prefill_span);
    let ttft = t0.elapsed().as_secs_f64();

    // Block-table view of the speculative cache: drafts advance it,
    // verification rewinds and overwrites.
    let mut pool = KvPool::solo(dims.max_seq);
    let table_len = prompt.len().min(dims.max_seq - 1);
    pool.alloc(0, &prompt[..table_len])?;

    let mut out: Vec<i32> = Vec::with_capacity(max_new);
    let mut pos = prompt.len();
    // `pending` = last sampled token not yet written into the cache.
    let mut pending = {
        let _s = tele.map(|t| t.span(Cat::Sample, "sample_first"));
        sampling::sample(&logits, sp, &mut rng)
    };
    out.push(pending);

    let mut accepted_total = 0usize;
    let mut rounds = 0usize;

    'outer: while out.len() < max_new && pending != tokenizer::EOS {
        if pos + k_window + 1 >= dims.max_seq {
            break;
        }
        rounds += 1;
        if let Some(t) = tele {
            t.next_tick();
        }
        let _round_span = tele.map(|t| t.span(Cat::Decode, "spec_round"));
        // ---- draft phase: K-1 cheap tokens after `pending` ----------
        let mut window = Vec::with_capacity(k_window);
        window.push(pending);
        let mut dkv_pos = pos;
        for _ in 0..k_window - 1 {
            let fed = *window.last().unwrap();
            let dl = exec.decode_step(&[SlotFeed {
                slot: 0,
                token: fed,
                pos: dkv_pos,
            }])?;
            // Drafts are greedy (standard for self-spec draft phase).
            window.push(sampling::greedy(&dl));
            pool.advance(0, fed)?;
            dkv_pos += 1;
        }
        // ---- verify phase: all K tokens in one full-model pass ------
        // The verify pass overwrites positions pos..pos+K: rewind the
        // block table and replay the window through it.
        pool.rewind_to(0, pos)?;
        for &w in &window {
            pool.advance(0, w)?;
        }
        let vl = exec.verify(0, &window, pos)?;
        let vocab = dims.vocab;

        // Longest prefix of drafts matching the full model (greedy).
        // vl[j] is the full model's next-token dist after window[j].
        let _accept_span = tele.map(|t| t.span(Cat::Sample, "accept"));
        let mut accepted = 0usize;
        for j in 1..k_window {
            let full_tok =
                sampling::greedy(&vl[(j - 1) * vocab..j * vocab]);
            if full_tok == window[j] {
                accepted += 1;
            } else {
                break;
            }
        }
        accepted_total += accepted;
        // Emit accepted drafts (window[1..=accepted]).
        for &d in window.iter().skip(1).take(accepted) {
            out.push(d);
            if out.len() >= max_new || d == tokenizer::EOS {
                pos += accepted + 1;
                break 'outer;
            }
        }
        // Bonus token from the verify logits at the last accepted slot.
        let bonus =
            sampling::greedy(&vl[accepted * vocab..(accepted + 1) * vocab]);
        out.push(bonus);
        // Cache now holds correct entries for window[0..=accepted] at
        // pos..pos+accepted; rewind the logical position there.
        pos += accepted + 1;
        pool.rewind_to(0, pos)?;
        pending = bonus;
    }

    pool.release(0)?;
    debug_assert!(pool.check_invariants().is_ok());
    Ok(GenResult {
        prompt_tokens: prompt.len(),
        decode_steps: out.len(),
        tokens: out,
        ttft,
        e2e: t0.elapsed().as_secs_f64(),
        accepted_drafts: accepted_total,
        draft_rounds: rounds,
    })
}

/// Numerically stable log-softmax over one logits row (max-shifted
/// log-sum-exp).
pub fn log_softmax(xs: &[f32]) -> Vec<f32> {
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let lse = xs.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
    xs.iter().map(|&x| x - lse).collect()
}

/// The `n` largest entries of `xs` as `(index, value)`, descending.
/// Ties keep index order (the sort is stable), so expansion is
/// deterministic.
pub fn top_n(xs: &[f32], n: usize) -> Vec<(usize, &f32)> {
    let mut v: Vec<(usize, &f32)> = xs.iter().enumerate().collect();
    v.sort_by(|a, b| b.1.partial_cmp(a.1).unwrap_or(Ordering::Equal));
    v.truncate(n);
    v
}

/// Knobs for [`generate_beam`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BeamConfig {
    /// Hypotheses kept per step (clamped to the executor's batch).
    pub beams: usize,
    /// Decode-step budget.
    pub max_steps: usize,
    /// GNMT length-normalization exponent (0 = raw log-prob).
    pub len_penalty: f32,
    /// Decoding starts from this token at position 0.
    pub bos: i32,
    /// A hypothesis emitting this token is finished (the token itself
    /// is not part of the returned sequence).
    pub eos: i32,
}

/// What [`generate_beam`] hands back: the best hypothesis plus the
/// paging counters that show the reorder ran as forks, not copies.
#[derive(Debug, Clone, PartialEq)]
pub struct BeamResult {
    /// Best hypothesis (EOS excluded), by normalized score.
    pub tokens: Vec<i32>,
    /// Length-normalized log-probability of `tokens`.
    pub score: f32,
    /// Decode steps taken (each steps all beams at once).
    pub decode_steps: usize,
    /// Block-table forks the beam reorders performed.
    pub beam_forks: u64,
    /// Copy-on-write page splits those forks later paid at divergence.
    pub cow_forks: u64,
    /// Wall-clock end-to-end seconds.
    pub e2e: f64,
}

/// Length-normalized beam search over a [`StepExecutor`].
///
/// The prompt (if any) is fed through `prefill_chunk` once as
/// encoder/cross-attention context; decoding then starts from
/// `cfg.bos`. Every hypothesis is a block table in a private
/// [`KvPool`]: a beam reorder forks the surviving parents' tables
/// (refcount bumps — no page is copied until a hypothesis diverges
/// within a shared page, which costs one COW split) and prunes dead
/// hypotheses with `release_discard`, leaving any cached prefix
/// untouched. The executor only sees one
/// [`StepExecutor::reorder_slots`] gather per step for whatever dense
/// per-slot state it still holds. This is the paper's Obs #4 fix
/// (beam-search KV churn) expressed in pages instead of copies.
///
/// Finished hypotheses are scored `logprob / len^len_penalty` (GNMT);
/// a hypothesis that never emits `cfg.eos` within `cfg.max_steps` is
/// scored over its current length.
pub fn generate_beam(exec: &mut impl StepExecutor,
                     tele: Option<&WorkerTracer>, prompt: &[i32],
                     cfg: &BeamConfig) -> Result<BeamResult> {
    const ROOT: u64 = 0;
    let t0 = Instant::now();
    let dims = exec.plan_dims();
    let bm = cfg.beams.max(1).min(dims.batch.max(1));
    let _tick_scope = tele.map(|t| t.tick_scope());

    if !prompt.is_empty() {
        let prefill_span = tele.map(|t| t.span(Cat::Prefill, "prefill"));
        exec.prefill_chunk(0, prompt, 0, true)?;
        drop(prefill_span);
    }

    // Worst case mid-reorder: the root anchor plus bm old and bm new
    // hypothesis tables, each at most one sequence deep.
    let mut pool = KvPool::new(
        (2 * bm + 1) * pages_for(dims.max_seq, DEFAULT_PAGE_SIZE),
        DEFAULT_PAGE_SIZE,
        dims.max_seq,
    );
    pool.alloc(ROOT, &[cfg.bos])?;
    let mut next_id: u64 = 1;
    // ids[b] = the block table behind hypothesis b (root for beam 0 at
    // step 0, a forked child afterwards). The root table stays live for
    // the whole search as the shared ancestor every fork chains off.
    let mut ids: Vec<Option<u64>> = vec![None; bm];
    ids[0] = Some(ROOT);

    let mut tokens = vec![cfg.bos; bm];
    let mut scores = vec![f32::NEG_INFINITY; bm];
    scores[0] = 0.0;
    let mut seqs: Vec<Vec<i32>> = vec![Vec::new(); bm];
    let mut finished: Vec<(Vec<i32>, f32)> = Vec::new();
    let mut decode_steps = 0usize;

    let budget = cfg.max_steps.min(dims.max_seq.saturating_sub(2));
    for step in 0..budget {
        if let Some(t) = tele {
            t.next_tick();
        }
        let _step_span =
            tele.map(|t| t.span(Cat::Decode, exec.step_span_name()));
        let feeds: Vec<SlotFeed> = (0..bm)
            .map(|b| SlotFeed { slot: b, token: tokens[b], pos: step })
            .collect();
        let logits = exec.decode_step(&feeds)?;
        decode_steps += 1;

        let mut new_tokens = vec![cfg.bos; bm];
        let mut new_scores = vec![f32::NEG_INFINITY; bm];
        let mut new_seqs: Vec<Vec<i32>> = vec![Vec::new(); bm];
        let mut src_idx = vec![0i32; bm];
        let mut filled = 0usize;
        {
            let _s = tele.map(|t| t.span(Cat::Sample, "beam_expand"));
            let mut candidates: Vec<(f32, usize, i32)> = Vec::new();
            for (b, &score) in scores.iter().enumerate().take(bm) {
                if score == f32::NEG_INFINITY {
                    continue;
                }
                let row = &logits[b * dims.vocab..(b + 1) * dims.vocab];
                let lp = log_softmax(row);
                for (tok, val) in top_n(&lp, bm + 1) {
                    candidates.push((score + *val, b, tok as i32));
                }
            }
            candidates.sort_by(|a, b| {
                b.0.partial_cmp(&a.0).unwrap_or(Ordering::Equal)
            });
            for &(score, src, tok) in &candidates {
                if tok == cfg.eos {
                    let len = seqs[src].len();
                    finished.push((
                        seqs[src].clone(),
                        score / ((len + 1) as f32).powf(cfg.len_penalty),
                    ));
                } else if filled < bm {
                    new_tokens[filled] = tok;
                    new_scores[filled] = score;
                    let mut s = seqs[src].clone();
                    s.push(tok);
                    new_seqs[filled] = s;
                    src_idx[filled] = src as i32;
                    filled += 1;
                }
                if filled == bm {
                    break;
                }
            }
        }
        if filled == 0 {
            break;
        }

        // The reorder, in pages: every surviving hypothesis forks its
        // parent's table (refcount bump) and advances by its own new
        // token (COW only where it diverges inside a shared page); the
        // superseded hypotheses are discarded without touching the
        // prefix cache.
        let mut new_ids: Vec<Option<u64>> = vec![None; bm];
        for b in 0..filled {
            let child = next_id;
            next_id += 1;
            let parent = ids[src_idx[b] as usize]
                .context("beam candidate came from a dead hypothesis")?;
            pool.fork(parent, child)?;
            pool.advance(child, new_tokens[b])?;
            new_ids[b] = Some(child);
        }
        for id in ids.iter().flatten() {
            if *id != ROOT {
                pool.release_discard(*id)?;
            }
        }
        ids = new_ids;
        exec.reorder_slots(&src_idx)?;

        tokens = new_tokens;
        scores = new_scores;
        seqs = new_seqs;
    }

    // Unfinished hypotheses compete at their current length.
    for b in 0..bm {
        if scores[b] == f32::NEG_INFINITY {
            continue;
        }
        let len = seqs[b].len().max(1);
        finished.push((
            std::mem::take(&mut seqs[b]),
            scores[b] / (len as f32).powf(cfg.len_penalty),
        ));
    }
    for id in ids.iter().flatten() {
        if *id != ROOT {
            pool.release_discard(*id)?;
        }
    }
    pool.release(ROOT)?;
    debug_assert!(pool.check_invariants().is_ok());
    let (beam_forks, cow_forks) =
        (pool.stats.beam_forks, pool.stats.cow_forks);

    let (tokens, score) = finished
        .into_iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(Ordering::Equal))
        .unwrap_or_default();
    Ok(BeamResult {
        tokens,
        score,
        decode_steps,
        beam_forks,
        cow_forks,
        e2e: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const VOCAB: usize = 16;
    const MAX_SEQ: usize = 64;

    fn one_hot(tok: i32) -> Vec<f32> {
        let mut l = vec![0.0f32; VOCAB];
        l[tok as usize] = 1.0;
        l
    }

    /// Deterministic mock: after a token at position p, the model
    /// "predicts" `next[p]` (a scripted sequence), one-hot.
    struct Scripted {
        next: Vec<i32>,
        /// Positions fed so far (mirrors a KV fill position).
        fed: usize,
        cap_prompt: bool,
        draft_next: Vec<i32>,
        verify_calls: usize,
    }

    impl Scripted {
        fn new(next: Vec<i32>) -> Self {
            Scripted {
                draft_next: next.clone(),
                next,
                fed: 0,
                cap_prompt: false,
                verify_calls: 0,
            }
        }

        fn at(seq: &[i32], pos: usize) -> i32 {
            seq.get(pos).copied().unwrap_or(3)
        }
    }

    impl StepExecutor for Scripted {
        fn plan_dims(&self) -> ExecDims {
            ExecDims { batch: 1, max_seq: MAX_SEQ, vocab: VOCAB }
        }

        fn prefill_chunk(&mut self, _slot: usize, tokens: &[i32],
                         start: usize, is_last: bool)
                         -> Result<Option<Vec<f32>>> {
            assert_eq!(start, self.fed);
            self.fed += tokens.len();
            if self.cap_prompt {
                return Ok(None);
            }
            Ok(if is_last {
                Some(one_hot(Self::at(&self.next, self.fed - 1)))
            } else {
                None
            })
        }

        fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>> {
            assert_eq!(feeds.len(), 1);
            // Draft path answers from `draft_next`; the plain decode
            // path has draft_next == next, so both loops share this.
            Ok(one_hot(Self::at(&self.draft_next, feeds[0].pos)))
        }

        fn verify(&mut self, _slot: usize, window: &[i32], start: usize)
                  -> Result<Vec<f32>> {
            self.verify_calls += 1;
            let mut out = Vec::with_capacity(window.len() * VOCAB);
            for j in 0..window.len() {
                out.extend(one_hot(Self::at(&self.next, start + j)));
            }
            Ok(out)
        }

        fn verify_window(&self) -> usize {
            4
        }
    }

    #[test]
    fn generate_follows_scripted_logits_greedily() {
        // Prompt fills positions 0..3; model then scripts 5,6,7,…
        let mut next = vec![0i32; MAX_SEQ];
        for (p, slot) in next.iter_mut().enumerate() {
            *slot = (5 + p as i32) % 15; // never EOS (=1): 5..=14,0,2..
        }
        next[3] = 9; // after the last prompt token, predict 9
        let mut exec = Scripted::new(next.clone());
        let r = generate(&mut exec, None, &[2, 3, 4, 2], 4,
                         &SamplingParams::greedy())
            .unwrap();
        // First token = prefill logits at pos 3 → 9; then the decode
        // chain follows next[4], next[5], …
        assert_eq!(r.tokens[0], 9);
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.tokens[1], next[4]);
        assert_eq!(r.tokens[2], next[5]);
        assert_eq!(r.decode_steps, 4);
        assert_eq!(r.prompt_tokens, 4);
    }

    #[test]
    fn generate_stops_at_eos() {
        let mut next = vec![7i32; MAX_SEQ];
        next[3] = 9;
        next[4] = tokenizer::EOS;
        let mut exec = Scripted::new(next);
        let r = generate(&mut exec, None, &[2, 3, 4, 2], 10,
                         &SamplingParams::greedy())
            .unwrap();
        assert_eq!(r.tokens, vec![9, tokenizer::EOS]);
    }

    #[test]
    fn generate_with_capped_prompt_emits_nothing() {
        // The eager contract: a prompt the executor cannot finish
        // feeding (sequence cap) yields Ok(None) and zero tokens.
        let mut exec = Scripted::new(vec![5; MAX_SEQ]);
        exec.cap_prompt = true;
        let r = generate(&mut exec, None, &[2, 3, 4], 8,
                         &SamplingParams::greedy())
            .unwrap();
        assert!(r.tokens.is_empty());
        assert_eq!(r.decode_steps, 0);
    }

    #[test]
    fn speculative_full_acceptance_advances_k_tokens_per_round() {
        // Draft and full model agree everywhere → every round accepts
        // all K−1 drafts and emits a bonus: K tokens per verify call.
        let mut next = vec![0i32; MAX_SEQ];
        for (p, slot) in next.iter_mut().enumerate() {
            *slot = 5 + (p as i32 % 9); // 5..=13, never EOS
        }
        let mut exec = Scripted::new(next);
        let r = generate_speculative(&mut exec, None, &[2, 3, 4], 12,
                                     &SamplingParams::greedy())
            .unwrap();
        assert_eq!(r.tokens.len(), 12);
        assert!(r.draft_rounds >= 1);
        // Full acceptance: accepted == (K−1) × rounds (modulo the
        // final truncated round).
        assert!(r.accepted_drafts >= (r.draft_rounds - 1) * 3);
        assert_eq!(exec.verify_calls, r.draft_rounds);
    }

    #[test]
    fn speculative_rejection_falls_back_to_bonus_token() {
        // Draft disagrees with the full model everywhere → zero
        // accepted drafts; each round emits exactly the bonus token.
        let mut next = vec![0i32; MAX_SEQ];
        for (p, slot) in next.iter_mut().enumerate() {
            *slot = 5 + (p as i32 % 7);
        }
        let mut exec = Scripted::new(next.clone());
        exec.draft_next = vec![14i32; MAX_SEQ]; // always wrong
        let r = generate_speculative(&mut exec, None, &[2, 3, 4], 6,
                                     &SamplingParams::greedy())
            .unwrap();
        assert_eq!(r.accepted_drafts, 0);
        // first token + one bonus per round
        assert_eq!(r.tokens.len(), 1 + r.draft_rounds);
        // The emitted chain still follows the *full* model: bonus after
        // window[0] at pos p is next[p].
        assert_eq!(r.tokens[1], Scripted::at(&next, 3));
    }

    const BEAM_VOCAB: usize = 8;

    /// Two-slot beam mock: logits are scripted per step (rows for both
    /// slots), and every `reorder_slots` call is recorded.
    struct ScriptedBeam {
        /// `rows[step][slot * BEAM_VOCAB ..]` = raw logits.
        rows: Vec<Vec<f32>>,
        step: usize,
        reorders: Vec<Vec<i32>>,
    }

    impl ScriptedBeam {
        /// Raw logits favoring `tok` overwhelmingly (one row).
        fn dominant(tok: usize) -> Vec<f32> {
            let mut r = vec![0.0f32; BEAM_VOCAB];
            r[tok] = 50.0;
            r
        }

        fn flat() -> Vec<f32> {
            vec![0.0f32; BEAM_VOCAB]
        }
    }

    impl StepExecutor for ScriptedBeam {
        fn plan_dims(&self) -> ExecDims {
            ExecDims { batch: 2, max_seq: 32, vocab: BEAM_VOCAB }
        }

        fn prefill_chunk(&mut self, _slot: usize, _tokens: &[i32],
                         _start: usize, _is_last: bool)
                         -> Result<Option<Vec<f32>>> {
            Ok(None)
        }

        fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>> {
            assert_eq!(feeds.len(), 2);
            assert_eq!(feeds[0].pos, self.step);
            let row = self.rows[self.step].clone();
            self.step += 1;
            Ok(row)
        }

        fn reorder_slots(&mut self, src: &[i32]) -> Result<()> {
            self.reorders.push(src.to_vec());
            Ok(())
        }
    }

    fn beam_cfg(max_steps: usize) -> BeamConfig {
        BeamConfig {
            beams: 2,
            max_steps,
            len_penalty: 0.0,
            bos: 0,
            eos: tokenizer::EOS,
        }
    }

    #[test]
    fn beam_follows_dominant_path_and_reorders_by_fork() {
        // Slot 0 carries the dominant chain 4 → 6 → EOS; slot 1's rows
        // are flat, so its hypotheses stay ~50 nats behind and never
        // win. The EOS at step 2 finishes hypothesis [4, 6].
        let mut exec = ScriptedBeam {
            rows: vec![
                [ScriptedBeam::dominant(4), ScriptedBeam::flat()].concat(),
                [ScriptedBeam::dominant(6), ScriptedBeam::flat()].concat(),
                [ScriptedBeam::dominant(tokenizer::EOS as usize),
                 ScriptedBeam::flat()]
                .concat(),
            ],
            step: 0,
            reorders: Vec::new(),
        };
        let r =
            generate_beam(&mut exec, None, &[], &beam_cfg(3)).unwrap();
        assert_eq!(r.tokens, vec![4, 6]);
        assert!(r.score > -1.0, "dominant path scores near zero nats");
        assert_eq!(r.decode_steps, 3);
        // Every step re-fills both beams from slot 0's candidates, so
        // every reorder is a fork of the step's best hypothesis.
        assert_eq!(exec.reorders, vec![vec![0, 0]; 3]);
        // 2 forks per reorder; each fork pays COW when it diverges
        // inside the shared tail page.
        assert_eq!(r.beam_forks, 6);
        assert!(r.cow_forks >= 1);
    }

    #[test]
    fn beam_scores_unfinished_hypotheses_at_budget() {
        // No EOS within the budget: the best live hypothesis wins with
        // a length-normalized score.
        let mut exec = ScriptedBeam {
            rows: vec![
                [ScriptedBeam::dominant(4), ScriptedBeam::flat()].concat(),
                [ScriptedBeam::dominant(6), ScriptedBeam::flat()].concat(),
            ],
            step: 0,
            reorders: Vec::new(),
        };
        let r =
            generate_beam(&mut exec, None, &[], &beam_cfg(2)).unwrap();
        assert_eq!(r.tokens, vec![4, 6]);
        assert_eq!(r.decode_steps, 2);
        assert_eq!(exec.reorders.len(), 2);
    }

    #[test]
    fn beam_on_executor_without_reorder_hook_errors() {
        // `Scripted` (bs=1, no reorder_slots) cannot run beam search:
        // the default hook refuses after the first expansion.
        let mut exec = Scripted::new(vec![5; MAX_SEQ]);
        let err = generate_beam(
            &mut exec,
            None,
            &[],
            &BeamConfig {
                beams: 1,
                max_steps: 2,
                len_penalty: 0.0,
                bos: 0,
                eos: tokenizer::EOS,
            },
        );
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("no beam reorder"));
    }

    #[test]
    fn top_n_is_stable_on_ties() {
        let xs = [1.0f32, 5.0, 1.0, 5.0];
        let idx: Vec<usize> =
            top_n(&xs, 4).into_iter().map(|(i, _)| i).collect();
        assert_eq!(idx, vec![1, 3, 0, 2]);
    }

    #[test]
    fn log_softmax_is_normalized() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f32 = lp.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(lp.iter().all(|&x| x < 0.0));
    }

    #[test]
    fn slot_state_errors_render() {
        let e = SlotStateError::MissingJob { slot: 2, request: 9 };
        assert!(e.to_string().contains("slot 2"));
        assert!(e.to_string().contains("request 9"));
        let any: anyhow::Error =
            SlotStateError::MissingPrefill { request: 4 }.into();
        assert!(any.downcast_ref::<SlotStateError>().is_some());
        assert_ne!(e, SlotStateError::MissingPrefill { request: 9 });
    }
}
