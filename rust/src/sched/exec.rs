//! The step-executor abstraction and the generic decode drivers.
//!
//! A [`StepExecutor`] is what one serving engine looks like to the
//! scheduler: it can feed prompt chunks (`prefill_chunk`), take one
//! decode step for a set of slots (`decode_step`), and — for
//! self-speculative engines — verify a drafted window in one pass
//! (`verify`). Four implementations exist:
//!
//! | executor                         | lives in                  |
//! |----------------------------------|---------------------------|
//! | `BatchedExecutor` (compiled graph, B slots) | `coordinator::server` |
//! | `GraphExecutor` (compiled graph, bs=1)      | `coordinator::decoder_loop` |
//! | `EagerExecutor` (per-op dispatch, bs=1)     | `coordinator::eager` |
//! | `LayerSkipExecutor` (draft/verify, bs=1)    | `coordinator::layerskip` |
//!
//! The drivers here replace the four hand-rolled generate loops:
//! [`generate`] runs the shared bs=1 prefill→sample→decode loop (the
//! compiled-graph and eager paths differ only in how their executor
//! consumes the prompt), and [`generate_speculative`] runs the
//! LayerSkip draft/verify round against the `decode_step` (draft) and
//! `verify` hooks. The batched worker's tick driver consumes a
//! [`TickPlan`](super::plan::TickPlan) against the same trait in
//! `coordinator::server::run_tick`.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::decoder_loop::GenResult;
use crate::coordinator::request::SamplingParams;
use crate::coordinator::sampling;
use crate::kvpool::KvPool;
use crate::models::tokenizer;
use crate::substrate::rng::Rng;
use crate::telemetry::tracer::{Cat, WorkerTracer};

/// Static dimensions the planner and drivers size their loops by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecDims {
    /// Decode slots the executor steps at once (1 for bs=1 engines).
    pub batch: usize,
    /// Sequence capacity per slot.
    pub max_seq: usize,
    /// Logits row width.
    pub vocab: usize,
}

/// One slot's input to a decode step: feed `token` at `pos`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotFeed {
    pub slot: usize,
    pub token: i32,
    pub pos: usize,
}

/// Structured slot-state errors for the batched worker: a live slot
/// whose bookkeeping went missing is surfaced through the request's
/// `Response` channel (or logged) instead of panicking the worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotStateError {
    /// A slot the plan expected to decode has no `SlotJob`.
    MissingJob { slot: usize, request: u64 },
    /// A planned chunk's request has no prefill state.
    MissingPrefill { request: u64 },
}

impl std::fmt::Display for SlotStateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SlotStateError::MissingJob { slot, request } => write!(
                f,
                "slot {slot} is live for request {request} but holds no \
                 decode job"
            ),
            SlotStateError::MissingPrefill { request } => write!(
                f,
                "request {request} was planned a prefill chunk but has \
                 no prefill state"
            ),
        }
    }
}

impl std::error::Error for SlotStateError {}

/// One serving engine, as seen by the scheduler.
pub trait StepExecutor {
    /// Batch width, sequence capacity, and vocab size.
    fn plan_dims(&self) -> ExecDims;

    /// Span name for one decode step (telemetry).
    fn step_span_name(&self) -> &'static str {
        "decode_step"
    }

    /// Feed prompt tokens `[start, start+len)` for `slot`. Returns the
    /// final position's logits when `is_last` completed the prompt;
    /// `Ok(None)` when the prompt is not finished — either because
    /// more chunks follow, or because the executor capped early (e.g.
    /// the prompt reaches the sequence capacity), in which case the
    /// driver generates nothing.
    fn prefill_chunk(&mut self, slot: usize, tokens: &[i32], start: usize,
                     is_last: bool) -> Result<Option<Vec<f32>>>;

    /// One decode step: feed each slot its token at its position,
    /// return logits `[batch × vocab]`. For a self-speculative
    /// executor this is the *draft* step.
    fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>>;

    /// Verify a drafted window of `verify_window()` tokens starting at
    /// `start` in one full-model pass; returns logits
    /// `[window × vocab]`. Only self-speculative executors implement
    /// this.
    fn verify(&mut self, _slot: usize, _window: &[i32], _start: usize)
              -> Result<Vec<f32>> {
        bail!("this executor has no verify stage")
    }

    /// Draft window size for [`generate_speculative`] (0 = not a
    /// speculative executor).
    fn verify_window(&self) -> usize {
        0
    }
}

/// The shared bs=1 generation loop: chunked prompt feed (the executor
/// decides how it consumes the chunk — one bucketed prefill for the
/// compiled graph, token-by-token for eager), then sample→decode with
/// the position bookkeeping running through a solo kvpool block table.
pub fn generate(exec: &mut impl StepExecutor, tele: Option<&WorkerTracer>,
                prompt: &[i32], max_new: usize, sp: &SamplingParams)
                -> Result<GenResult> {
    let t0 = Instant::now();
    let dims = exec.plan_dims();
    let _tick_scope = tele.map(|t| t.tick_scope());
    let mut rng = Rng::new(sp.seed);
    let prefill_span = tele.map(|t| t.span(Cat::Prefill, "prefill"));
    let first_logits = exec.prefill_chunk(0, prompt, 0, true)?;
    drop(prefill_span);
    let ttft = t0.elapsed().as_secs_f64();
    let mut pool = KvPool::solo(dims.max_seq);
    let table_len = prompt.len().min(dims.max_seq - 1);
    pool.alloc(0, &prompt[..table_len])?;
    let mut pos = prompt.len();
    let mut out = Vec::with_capacity(max_new);
    // `None` means the executor capped before finishing the prompt
    // (eager stops feeding at the sequence capacity): emit nothing.
    if let Some(mut logits) = first_logits {
        for _ in 0..max_new {
            if let Some(t) = tele {
                t.next_tick();
            }
            let _step_span =
                tele.map(|t| t.span(Cat::Decode, exec.step_span_name()));
            let tok = {
                let _s = tele.map(|t| t.span(Cat::Sample, "sample"));
                sampling::sample(&logits, sp, &mut rng)
            };
            out.push(tok);
            if tok == tokenizer::EOS || pos + 1 >= dims.max_seq {
                break;
            }
            if out.len() >= max_new {
                break;
            }
            logits =
                exec.decode_step(&[SlotFeed { slot: 0, token: tok, pos }])?;
            pos = pool.advance(0, tok)?;
        }
    }
    pool.release(0)?;
    debug_assert!(pool.check_invariants().is_ok());
    Ok(GenResult {
        prompt_tokens: prompt.len(),
        decode_steps: out.len(),
        tokens: out,
        ttft,
        e2e: t0.elapsed().as_secs_f64(),
        accepted_drafts: 0,
        draft_rounds: 0,
    })
}

/// The self-speculative round (LayerSkip, §4.3): draft
/// `verify_window() − 1` cheap tokens through `decode_step`, verify the
/// whole window in one `verify` pass, accept the longest matching
/// prefix greedily, emit a bonus token from the verify logits, and
/// rewind the block table to the accepted position.
pub fn generate_speculative(exec: &mut impl StepExecutor,
                            tele: Option<&WorkerTracer>, prompt: &[i32],
                            max_new: usize, sp: &SamplingParams)
                            -> Result<GenResult> {
    let t0 = Instant::now();
    let dims = exec.plan_dims();
    let k_window = exec.verify_window();
    if k_window < 2 {
        bail!("speculative decoding needs a verify window ≥ 2");
    }
    let mut rng = Rng::new(sp.seed);
    let _tick_scope = tele.map(|t| t.tick_scope());
    let prefill_span = tele.map(|t| t.span(Cat::Prefill, "prefill"));
    let logits = exec
        .prefill_chunk(0, prompt, 0, true)?
        .context("speculative prefill must produce logits")?;
    drop(prefill_span);
    let ttft = t0.elapsed().as_secs_f64();

    // Block-table view of the speculative cache: drafts advance it,
    // verification rewinds and overwrites.
    let mut pool = KvPool::solo(dims.max_seq);
    let table_len = prompt.len().min(dims.max_seq - 1);
    pool.alloc(0, &prompt[..table_len])?;

    let mut out: Vec<i32> = Vec::with_capacity(max_new);
    let mut pos = prompt.len();
    // `pending` = last sampled token not yet written into the cache.
    let mut pending = {
        let _s = tele.map(|t| t.span(Cat::Sample, "sample_first"));
        sampling::sample(&logits, sp, &mut rng)
    };
    out.push(pending);

    let mut accepted_total = 0usize;
    let mut rounds = 0usize;

    'outer: while out.len() < max_new && pending != tokenizer::EOS {
        if pos + k_window + 1 >= dims.max_seq {
            break;
        }
        rounds += 1;
        if let Some(t) = tele {
            t.next_tick();
        }
        let _round_span = tele.map(|t| t.span(Cat::Decode, "spec_round"));
        // ---- draft phase: K-1 cheap tokens after `pending` ----------
        let mut window = Vec::with_capacity(k_window);
        window.push(pending);
        let mut dkv_pos = pos;
        for _ in 0..k_window - 1 {
            let fed = *window.last().unwrap();
            let dl = exec.decode_step(&[SlotFeed {
                slot: 0,
                token: fed,
                pos: dkv_pos,
            }])?;
            // Drafts are greedy (standard for self-spec draft phase).
            window.push(sampling::greedy(&dl));
            pool.advance(0, fed)?;
            dkv_pos += 1;
        }
        // ---- verify phase: all K tokens in one full-model pass ------
        // The verify pass overwrites positions pos..pos+K: rewind the
        // block table and replay the window through it.
        pool.rewind_to(0, pos)?;
        for &w in &window {
            pool.advance(0, w)?;
        }
        let vl = exec.verify(0, &window, pos)?;
        let vocab = dims.vocab;

        // Longest prefix of drafts matching the full model (greedy).
        // vl[j] is the full model's next-token dist after window[j].
        let _accept_span = tele.map(|t| t.span(Cat::Sample, "accept"));
        let mut accepted = 0usize;
        for j in 1..k_window {
            let full_tok =
                sampling::greedy(&vl[(j - 1) * vocab..j * vocab]);
            if full_tok == window[j] {
                accepted += 1;
            } else {
                break;
            }
        }
        accepted_total += accepted;
        // Emit accepted drafts (window[1..=accepted]).
        for &d in window.iter().skip(1).take(accepted) {
            out.push(d);
            if out.len() >= max_new || d == tokenizer::EOS {
                pos += accepted + 1;
                break 'outer;
            }
        }
        // Bonus token from the verify logits at the last accepted slot.
        let bonus =
            sampling::greedy(&vl[accepted * vocab..(accepted + 1) * vocab]);
        out.push(bonus);
        // Cache now holds correct entries for window[0..=accepted] at
        // pos..pos+accepted; rewind the logical position there.
        pos += accepted + 1;
        pool.rewind_to(0, pos)?;
        pending = bonus;
    }

    pool.release(0)?;
    debug_assert!(pool.check_invariants().is_ok());
    Ok(GenResult {
        prompt_tokens: prompt.len(),
        decode_steps: out.len(),
        tokens: out,
        ttft,
        e2e: t0.elapsed().as_secs_f64(),
        accepted_drafts: accepted_total,
        draft_rounds: rounds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const VOCAB: usize = 16;
    const MAX_SEQ: usize = 64;

    fn one_hot(tok: i32) -> Vec<f32> {
        let mut l = vec![0.0f32; VOCAB];
        l[tok as usize] = 1.0;
        l
    }

    /// Deterministic mock: after a token at position p, the model
    /// "predicts" `next[p]` (a scripted sequence), one-hot.
    struct Scripted {
        next: Vec<i32>,
        /// Positions fed so far (mirrors a KV fill position).
        fed: usize,
        cap_prompt: bool,
        draft_next: Vec<i32>,
        verify_calls: usize,
    }

    impl Scripted {
        fn new(next: Vec<i32>) -> Self {
            Scripted {
                draft_next: next.clone(),
                next,
                fed: 0,
                cap_prompt: false,
                verify_calls: 0,
            }
        }

        fn at(seq: &[i32], pos: usize) -> i32 {
            seq.get(pos).copied().unwrap_or(3)
        }
    }

    impl StepExecutor for Scripted {
        fn plan_dims(&self) -> ExecDims {
            ExecDims { batch: 1, max_seq: MAX_SEQ, vocab: VOCAB }
        }

        fn prefill_chunk(&mut self, _slot: usize, tokens: &[i32],
                         start: usize, is_last: bool)
                         -> Result<Option<Vec<f32>>> {
            assert_eq!(start, self.fed);
            self.fed += tokens.len();
            if self.cap_prompt {
                return Ok(None);
            }
            Ok(if is_last {
                Some(one_hot(Self::at(&self.next, self.fed - 1)))
            } else {
                None
            })
        }

        fn decode_step(&mut self, feeds: &[SlotFeed]) -> Result<Vec<f32>> {
            assert_eq!(feeds.len(), 1);
            // Draft path answers from `draft_next`; the plain decode
            // path has draft_next == next, so both loops share this.
            Ok(one_hot(Self::at(&self.draft_next, feeds[0].pos)))
        }

        fn verify(&mut self, _slot: usize, window: &[i32], start: usize)
                  -> Result<Vec<f32>> {
            self.verify_calls += 1;
            let mut out = Vec::with_capacity(window.len() * VOCAB);
            for j in 0..window.len() {
                out.extend(one_hot(Self::at(&self.next, start + j)));
            }
            Ok(out)
        }

        fn verify_window(&self) -> usize {
            4
        }
    }

    #[test]
    fn generate_follows_scripted_logits_greedily() {
        // Prompt fills positions 0..3; model then scripts 5,6,7,…
        let mut next = vec![0i32; MAX_SEQ];
        for (p, slot) in next.iter_mut().enumerate() {
            *slot = (5 + p as i32) % 15; // never EOS (=1): 5..=14,0,2..
        }
        next[3] = 9; // after the last prompt token, predict 9
        let mut exec = Scripted::new(next.clone());
        let r = generate(&mut exec, None, &[2, 3, 4, 2], 4,
                         &SamplingParams::greedy())
            .unwrap();
        // First token = prefill logits at pos 3 → 9; then the decode
        // chain follows next[4], next[5], …
        assert_eq!(r.tokens[0], 9);
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.tokens[1], next[4]);
        assert_eq!(r.tokens[2], next[5]);
        assert_eq!(r.decode_steps, 4);
        assert_eq!(r.prompt_tokens, 4);
    }

    #[test]
    fn generate_stops_at_eos() {
        let mut next = vec![7i32; MAX_SEQ];
        next[3] = 9;
        next[4] = tokenizer::EOS;
        let mut exec = Scripted::new(next);
        let r = generate(&mut exec, None, &[2, 3, 4, 2], 10,
                         &SamplingParams::greedy())
            .unwrap();
        assert_eq!(r.tokens, vec![9, tokenizer::EOS]);
    }

    #[test]
    fn generate_with_capped_prompt_emits_nothing() {
        // The eager contract: a prompt the executor cannot finish
        // feeding (sequence cap) yields Ok(None) and zero tokens.
        let mut exec = Scripted::new(vec![5; MAX_SEQ]);
        exec.cap_prompt = true;
        let r = generate(&mut exec, None, &[2, 3, 4], 8,
                         &SamplingParams::greedy())
            .unwrap();
        assert!(r.tokens.is_empty());
        assert_eq!(r.decode_steps, 0);
    }

    #[test]
    fn speculative_full_acceptance_advances_k_tokens_per_round() {
        // Draft and full model agree everywhere → every round accepts
        // all K−1 drafts and emits a bonus: K tokens per verify call.
        let mut next = vec![0i32; MAX_SEQ];
        for (p, slot) in next.iter_mut().enumerate() {
            *slot = 5 + (p as i32 % 9); // 5..=13, never EOS
        }
        let mut exec = Scripted::new(next);
        let r = generate_speculative(&mut exec, None, &[2, 3, 4], 12,
                                     &SamplingParams::greedy())
            .unwrap();
        assert_eq!(r.tokens.len(), 12);
        assert!(r.draft_rounds >= 1);
        // Full acceptance: accepted == (K−1) × rounds (modulo the
        // final truncated round).
        assert!(r.accepted_drafts >= (r.draft_rounds - 1) * 3);
        assert_eq!(exec.verify_calls, r.draft_rounds);
    }

    #[test]
    fn speculative_rejection_falls_back_to_bonus_token() {
        // Draft disagrees with the full model everywhere → zero
        // accepted drafts; each round emits exactly the bonus token.
        let mut next = vec![0i32; MAX_SEQ];
        for (p, slot) in next.iter_mut().enumerate() {
            *slot = 5 + (p as i32 % 7);
        }
        let mut exec = Scripted::new(next.clone());
        exec.draft_next = vec![14i32; MAX_SEQ]; // always wrong
        let r = generate_speculative(&mut exec, None, &[2, 3, 4], 6,
                                     &SamplingParams::greedy())
            .unwrap();
        assert_eq!(r.accepted_drafts, 0);
        // first token + one bonus per round
        assert_eq!(r.tokens.len(), 1 + r.draft_rounds);
        // The emitted chain still follows the *full* model: bonus after
        // window[0] at pos p is next[p].
        assert_eq!(r.tokens[1], Scripted::at(&next, 3));
    }

    #[test]
    fn slot_state_errors_render() {
        let e = SlotStateError::MissingJob { slot: 2, request: 9 };
        assert!(e.to_string().contains("slot 2"));
        assert!(e.to_string().contains("request 9"));
        let any: anyhow::Error =
            SlotStateError::MissingPrefill { request: 4 }.into();
        assert!(any.downcast_ref::<SlotStateError>().is_some());
        assert_ne!(e, SlotStateError::MissingPrefill { request: 9 });
    }
}
