//! mmserve CLI — leader entrypoint.
//!
//! Subcommands (the list below is *derived* from [`SUBCOMMANDS`], the
//! single source of truth that also drives dispatch, so the help text
//! cannot drift):
//!
//! * `serve`        — start the multi-model router and run a demo batch
//!                    of requests against it (in-process client).
//! * `characterize` — print the paper's Figure-4-style operator
//!                    breakdown from the analytical device model.
//! * `autoquant`    — run the §4.2 quantization calibration on real
//!                    executables.
//! * `stages`       — list AOT stages available per model.
//! * `trace`        — run a traced request mix; write a Chrome-trace
//!                    JSON and print the measured breakdown with
//!                    idle-gap attribution next to the perfmodel
//!                    projection.
//! * `kv`           — replay a mixed workload through the paged KV
//!                    pool vs. the dense slot baseline (same page
//!                    budget) and print occupancy, prefix hit rate,
//!                    eviction/preemption counters, and the Table-3
//!                    paged-vs-dense achievable-batch projection;
//!                    `--replicas N` additionally replays the workload
//!                    over N simulated workers under each routing
//!                    policy (round-robin / least-loaded /
//!                    prefix-affinity) and prints aggregate hit rate +
//!                    simulated TTFT/TBT per policy; `--shards D`
//!                    splits every page budget across D device arenas
//!                    and prints the sharded-vs-monolithic capacity
//!                    table with per-shard occupancy; `--disaggregate`
//!                    A/Bs colocated vs split prefill/decode workers
//!                    over the priced transfer fabric (KV handoff on
//!                    the network link) and `--fabric-json` writes
//!                    that A/B for the CI gate; `--arrivals` replaces
//!                    the pre-queued closed loop with an open-loop
//!                    timestamped stream (Poisson / diurnal / burst,
//!                    Zipf tenants, warm-prefix follow-ups) and
//!                    `--autoscale MIN:MAX` A/Bs an elastic fleet
//!                    against fixed min/max fleets (`--autoscale-json`
//!                    writes that A/B for the CI gate); `--bench-json`
//!                    writes the metrics for the CI perf gate.
//! * `stats`        — replay a sharded multi-replica workload with the
//!                    live metrics plane attached and render the fleet
//!                    dashboard (per-replica, per-shard, per-tenant
//!                    rows with streaming p50/p99 TTFT/TBT);
//!                    `--metrics-out` writes the Prometheus text
//!                    exposition, `--record-out` the flight-recorder
//!                    JSONL dumps, `--kill R@K` injects a replica
//!                    crash; per-tenant rows carry modeled Joules and
//!                    tokens-per-Joule from the causal ledger.
//! * `explain`      — replay with the per-request causal cost ledger
//!                    attached: `--request <id>` prints one request's
//!                    causal timeline, cost buckets and Joule
//!                    attribution; `--tail p99` / `--slowest K` the
//!                    tail-latency explainer table naming each slow
//!                    request's dominant cause; `--ledger-out` writes
//!                    the ledger JSONL, `--bench-json` ledger cost +
//!                    tokens-per-Joule metrics for the CI perf gate.

use anyhow::{bail, Result};

use mmserve::coordinator::autoquant;
use mmserve::coordinator::opts::{AttnImpl, ExecMode, OptConfig, QuantMode};
use mmserve::coordinator::request::{Request, RequestInput, SamplingParams};
use mmserve::coordinator::seamless_pipe::ReorderMode;
use mmserve::coordinator::server::{collect_stats, render_replica_reports,
                                   Router, RouterConfig};
use mmserve::kvpool::replay::{render_chunk_comparison, render_comparison,
                              render_family_table,
                              render_shard_comparison, replay,
                              MixSpec, ReplayConfig, ReplayResult};
use mmserve::kvpool::KvPoolConfig;
use mmserve::models::{ModelKind, TaskKind};
use mmserve::perfmodel::breakdown::render;
use mmserve::perfmodel::configs as paper_configs;
use mmserve::perfmodel::device::DeviceSpec;
use mmserve::perfmodel::fabric::FabricSpec;
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::standard_breakdown_rows;
use mmserve::routing::autoscale::{autoscale_replay, compare_autoscale,
                                  render_autoscale_comparison,
                                  render_phase_ttft,
                                  render_scale_timeline,
                                  AutoscaleComparison,
                                  AutoscaleReplayConfig,
                                  AutoscaleReplayResult, AutoscaleSpec};
use mmserve::routing::replay::{compare_disaggregation, compare_policies,
                               render_disagg_comparison,
                               render_policy_comparison,
                               render_worker_counters, routing_replay,
                               routing_replay_instrumented,
                               routing_replay_live, KillSpec,
                               RoutingReplayConfig, RoutingReplayResult};
use mmserve::routing::RoutingPolicy;
use mmserve::runtime::engine::Engine;
use mmserve::substrate::cli::Command;
use mmserve::substrate::json::Json;
use mmserve::substrate::table::Table;
use mmserve::telemetry::chrome_trace;
use mmserve::telemetry::ledger::energy::{EnergyBreakdown, EnergyModel,
                                         ModelFamily};
use mmserve::telemetry::ledger::explain::{parse_tail, render_request,
                                          render_rows, slowest_rows,
                                          tail_rows};
use mmserve::telemetry::ledger::RequestLedger;
use mmserve::telemetry::live::sampler::{
    CACHED_PAGES, CAPACITY_WAIT_TICKS_TOTAL, FREE_PAGES, LIVE_PAGES,
    PREEMPTIONS_TOTAL, PREFIX_HIT_RATE, QUEUE_DEPTH,
    REQUESTS_COMPLETED_TOTAL, SHARD_SPILLS_TOTAL, TBT_MS, TICKS_TOTAL,
    TOKENS_DECODED_TOTAL, TTFT_MS,
};
use mmserve::telemetry::live::{prometheus, FlightRecorder, LiveMetrics,
                               SketchSnapshot};
use mmserve::telemetry::tracer::Tracer;
use mmserve::telemetry::TraceReport;
use mmserve::workload::arrivals::{ArrivalPhase, ArrivalSpec};

/// One CLI subcommand: its name, a one-line summary, and its entry
/// point. `usage()` and `run()` both read this table — adding a
/// subcommand here is the only step needed to register it.
struct Subcommand {
    name: &'static str,
    summary: &'static str,
    run: fn(&[String]) -> Result<()>,
}

const SUBCOMMANDS: &[Subcommand] = &[
    Subcommand {
        name: "serve",
        summary: "start the router and serve a demo request batch",
        run: cmd_serve,
    },
    Subcommand {
        name: "characterize",
        summary: "Figure-4-style breakdown from the device model",
        run: cmd_characterize,
    },
    Subcommand {
        name: "autoquant",
        summary: "quantization calibration on real executables (§4.2)",
        run: cmd_autoquant,
    },
    Subcommand {
        name: "stages",
        summary: "list AOT stages available per model",
        run: cmd_stages,
    },
    Subcommand {
        name: "trace",
        summary: "trace a request mix; export Chrome trace + breakdown",
        run: cmd_trace,
    },
    Subcommand {
        name: "kv",
        summary: "replay a workload through the paged KV pool vs dense",
        run: cmd_kv,
    },
    Subcommand {
        name: "stats",
        summary: "live-metrics fleet dashboard over a replayed workload",
        run: cmd_stats,
    },
    Subcommand {
        name: "explain",
        summary: "causal cost ledger: tail-latency explainer + Joules",
        run: cmd_explain,
    },
];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("mmserve: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    let names: Vec<&str> = SUBCOMMANDS.iter().map(|s| s.name).collect();
    let mut s = format!("mmserve <{}> [options]\n", names.join("|"));
    for sub in SUBCOMMANDS {
        s.push_str(&format!("  {:<13} {}\n", sub.name, sub.summary));
    }
    s.push_str("run `mmserve <cmd> --help` for command options");
    s
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &argv[1..];
    if let Some(sub) = SUBCOMMANDS.iter().find(|s| s.name == cmd.as_str()) {
        return (sub.run)(rest);
    }
    match cmd.as_str() {
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn opt_from_args(a: &mmserve::substrate::cli::Args) -> OptConfig {
    let mut opt = OptConfig::baseline();
    if a.flag("sdpa") {
        opt.attn = AttnImpl::Flash;
    }
    if a.flag("eager") {
        opt.exec = ExecMode::Eager;
    }
    match a.get_or("quant", "f32").as_str() {
        "int8wo" => opt.quant = QuantMode::Int8WeightOnly,
        "int8dyn" => opt.quant = QuantMode::Int8Dynamic,
        _ => {}
    }
    if a.flag("layerskip") {
        opt.layerskip = true;
    }
    opt
}

fn parse_policy(a: &mmserve::substrate::cli::Args) -> Result<RoutingPolicy> {
    let s = a.get_or("policy", "prefix-affinity");
    RoutingPolicy::parse(&s).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown policy {s:?} (round-robin|least-loaded|prefix-affinity)"
        )
    })
}

fn parse_models(a: &mmserve::substrate::cli::Args) -> Result<Vec<ModelKind>> {
    let models: Vec<ModelKind> = a
        .get_or("models", "llama")
        .split(',')
        .filter_map(ModelKind::parse)
        .collect();
    if models.is_empty() {
        bail!("no valid models given");
    }
    Ok(models)
}

/// A representative request for one model family (used by the demo
/// batch in `serve` warmups and by the `trace` request mix).
fn demo_request(router: &Router, model: ModelKind, i: usize,
                max_new: usize) -> Request {
    let prompts = [
        "write a function to reverse a string",
        "def fib(n): compute the fibonacci numbers",
        "explain the borrow checker",
        "sort a list of integers in rust",
    ];
    match model {
        ModelKind::Llama => {
            let mut req = Request::text(router.fresh_id(),
                                        TaskKind::TextToText,
                                        prompts[i % prompts.len()], max_new);
            req.sampling = SamplingParams::greedy();
            req
        }
        ModelKind::Chameleon => Request {
            id: router.fresh_id(),
            task: TaskKind::ImageToText,
            input: RequestInput::Image {
                pixels: vec![0.25 + 0.1 * (i % 5) as f32; 64 * 64],
                h: 64,
                w: 64,
            },
            max_new_tokens: max_new,
            sampling: SamplingParams::greedy(),
        },
        ModelKind::Seamless => Request {
            id: router.fresh_id(),
            task: TaskKind::TextToTextTrans,
            input: RequestInput::Text(prompts[i % prompts.len()].into()),
            max_new_tokens: max_new,
            sampling: SamplingParams::greedy(),
        },
        ModelKind::Hstu => Request {
            id: router.fresh_id(),
            task: TaskKind::HistoryToAction,
            input: RequestInput::History(
                (0..120 + (i % 4) * 30).map(|k| (k * 13 % 6000) as i32)
                    .collect(),
            ),
            max_new_tokens: 0,
            sampling: SamplingParams::greedy(),
        },
    }
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "serve a demo request batch")
        .opt("models", "comma list of models", Some("llama"))
        .opt("requests", "number of demo requests", Some("8"))
        .opt("max-new", "max new tokens per request", Some("16"))
        .opt("batch", "decode batch size", Some("4"))
        .opt("quant", "f32|int8wo|int8dyn", Some("f32"))
        .opt("prefill-budget", "prefill token budget per tick (0 = off)",
             Some("0"))
        .opt("chunk-prefill",
             "chunked prefill: max new prompt tokens per tick (0 = whole)",
             Some("0"))
        .opt("replicas", "worker threads per model family", Some("1"))
        .opt("shards",
             "device arenas each worker's KV page budget is split across",
             Some("1"))
        .opt("policy",
             "replica routing: round-robin|least-loaded|prefix-affinity",
             Some("prefix-affinity"))
        .flag("disaggregate",
              "split replicas into prefill/decode tiers; print the \
               modeled colocated-vs-disaggregated A/B")
        .flag("sdpa", "enable the flash-attention stages")
        .flag("eager", "per-op dispatch (launch-overhead baseline)")
        .flag("layerskip", "self-speculative decoding")
        .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let models = parse_models(&a)?;
    let opt = opt_from_args(&a);
    let n = a.get_usize("requests", 8);
    let max_new = a.get_usize("max-new", 16);
    let disaggregate = a.flag("disaggregate");
    if a.get_usize("chunk-prefill", 0) > 0
        && a.get_usize("prefill-budget", 0) > 0
    {
        eprintln!(
            "mmserve: note: --chunk-prefill is the per-tick budget in \
             chunked mode; --prefill-budget is ignored"
        );
    }
    let replicas = a.get_usize("replicas", 1).max(1);
    let shards = a.get_usize("shards", 1).max(1);
    let policy = parse_policy(&a)?;

    println!(
        "starting router: models={models:?} opt=[{opt}] \
         replicas={replicas} shards={shards} policy={policy}"
    );
    let router = Router::start(
        &mmserve::artifacts_dir(),
        RouterConfig {
            models: models.clone(),
            opt,
            reorder: ReorderMode::Fused,
            batch: a.get_usize("batch", 4),
            prefill_budget: a.get_usize("prefill-budget", 0),
            chunk_prefill: a.get_usize("chunk-prefill", 0),
            kv: KvPoolConfig { shards, ..KvPoolConfig::default() },
            tracer: None,
            live: None,
            flight: None,
            ledger: None,
            replicas,
            policy,
            disaggregate,
        },
    );

    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n {
        let req = demo_request(&router, models[i % models.len()], i, max_new);
        rxs.push(router.submit(req)?);
    }
    let mut responses = Vec::new();
    for rx in rxs {
        responses.push(rx.recv()??);
    }
    let stats = collect_stats(&responses, t0.elapsed().as_secs_f64());
    println!("{}", stats.report());
    for r in responses.iter().take(2) {
        if let mmserve::coordinator::request::ResponseOutput::Text(t) =
            &r.output
        {
            println!("  [{}] {} tokens: {:?}", r.id, r.decode_steps, t);
        }
    }
    if replicas > 1 {
        println!("\n== replica routing ({policy}) ==");
        println!("{}", render_replica_reports(&router.replica_reports()));
    }
    router.shutdown();
    if disaggregate {
        // The priced prefill→decode handoff lives on the simulated
        // plane; show the modeled A/B for the same fleet size on a
        // long-prompt shared-prefix mix (the regime disaggregation
        // targets).
        let rcfg = RoutingReplayConfig {
            base: ReplayConfig {
                requests: 48,
                tenants: 2,
                long_percent: 50,
                long_prompt: (96, 200),
                total_pages: 192,
                batch_slots: 12,
                fabric: Some(FabricSpec::paper(
                    paper_configs::LLAMA_7B.kv_bytes_per_token(),
                )),
                ..ReplayConfig::default()
            },
            replicas: replicas.max(2),
            ..RoutingReplayConfig::default()
        };
        let (colo, disagg) =
            compare_disaggregation(&rcfg, RoutingPolicy::LeastLoaded);
        println!(
            "\n== modeled disaggregation A/B ({} workers, least-loaded, \
             simulated clock) ==",
            rcfg.replicas
        );
        println!("{}", render_disagg_comparison(&colo, &disagg));
    }
    Ok(())
}

fn cmd_characterize(argv: &[String]) -> Result<()> {
    let cmd = Command::new("characterize",
                           "Figure-4 style breakdown (device model)")
        .opt("device", "A100|H100", Some("A100"))
        .flag("sys-opt", "apply SDPA+compile+AutoQuant levers")
        .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let dev: &DeviceSpec = DeviceSpec::by_name(&a.get_or("device", "A100"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let lv = if a.flag("sys-opt") {
        Levers::sys_opt()
    } else {
        Levers::baseline()
    };
    let rows = standard_breakdown_rows(dev, &lv);
    println!("{}", render(&rows));
    Ok(())
}

fn cmd_autoquant(argv: &[String]) -> Result<()> {
    let cmd = Command::new("autoquant", "calibrate quantization (§4.2)")
        .opt("model", "llama|chameleon", Some("llama"))
        .opt("iters", "timing iterations", Some("20"))
        .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let dir = mmserve::artifacts_dir().join(a.get_or("model", "llama"));
    let engine = Engine::load(&dir)?;
    let rep = autoquant::calibrate_decode(&engine, a.get_usize("iters", 20))?;
    for t in &rep.timings {
        println!("  {:<24} {:>9.3} ms", t.stage, t.mean_s * 1e3);
    }
    println!("chosen: {:?}", rep.chosen);
    Ok(())
}

fn cmd_stages(argv: &[String]) -> Result<()> {
    let cmd = Command::new("stages", "list AOT stages per model")
        .opt("model", "model dir name", Some("llama"))
        .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let dir = mmserve::artifacts_dir().join(a.get_or("model", "llama"));
    let man = mmserve::runtime::manifest::Manifest::load(&dir)?;
    println!("model {} — {} stages", man.model, man.stages.len());
    for name in man.stage_names() {
        let s = man.stage(name)?;
        println!("  {:<28} {} weights, {} args, {} outputs",
                 name, s.weights.len(), s.args.len(), s.outputs.len());
    }
    Ok(())
}

fn cmd_trace(argv: &[String]) -> Result<()> {
    let cmd = Command::new("trace",
                           "trace a request mix; write Chrome-trace JSON")
        .opt("models", "comma list of models", Some("llama"))
        .opt("requests", "number of traced requests", Some("8"))
        .opt("max-new", "max new tokens per request", Some("16"))
        .opt("batch", "decode batch size", Some("4"))
        .opt("quant", "f32|int8wo|int8dyn", Some("f32"))
        .opt("out", "Chrome-trace output path", Some("trace.json"))
        .opt("device", "A100|H100 for the perfmodel projection",
             Some("A100"))
        .opt("chunk-prefill",
             "chunked prefill: max new prompt tokens per tick (0 = whole)",
             Some("0"))
        .opt("replicas", "worker threads per model family", Some("1"))
        .opt("shards",
             "device arenas each worker's KV page budget is split across",
             Some("1"))
        .opt("policy",
             "replica routing: round-robin|least-loaded|prefix-affinity",
             Some("prefix-affinity"))
        .flag("sdpa", "enable the flash-attention stages")
        .flag("eager", "per-op dispatch (launch-overhead baseline)")
        .flag("layerskip", "self-speculative decoding")
        .flag("trace-warmup", "include compile/warmup in the trace")
        .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let models = parse_models(&a)?;
    let opt = opt_from_args(&a);
    let n = a.get_usize("requests", 8);
    let max_new = a.get_usize("max-new", 16);
    let out = a.get_or("out", "trace.json");
    let replicas = a.get_usize("replicas", 1).max(1);
    let shards = a.get_usize("shards", 1).max(1);
    let policy = parse_policy(&a)?;

    // Tracing starts disabled so the compile-heavy warmup pass doesn't
    // drown the steady-state timeline (--trace-warmup keeps it).
    let tracer = if a.flag("trace-warmup") {
        Tracer::new()
    } else {
        Tracer::off()
    };
    println!(
        "starting traced router: models={models:?} opt=[{opt}] \
         replicas={replicas} policy={policy}"
    );
    let router = Router::start(
        &mmserve::artifacts_dir(),
        RouterConfig {
            models: models.clone(),
            opt,
            reorder: ReorderMode::Fused,
            batch: a.get_usize("batch", 4),
            prefill_budget: 0,
            chunk_prefill: a.get_usize("chunk-prefill", 0),
            kv: KvPoolConfig { shards, ..KvPoolConfig::default() },
            tracer: Some(tracer.clone()),
            live: None,
            flight: None,
            ledger: None,
            replicas,
            policy,
            disaggregate: false,
        },
    );

    // Warmup: one request per replica per model compiles the stages.
    // Submitted together: the queued gauge is bumped synchronously
    // before each send and the replicas are still loading engines
    // (they cannot dequeue yet), so depth-aware routing spreads the
    // batch one per replica deterministically.
    for (i, &m) in models.iter().enumerate() {
        let warm_rxs: Vec<_> = (0..replicas)
            .map(|r| {
                router.submit(demo_request(&router, m, i + r, max_new))
            })
            .collect::<Result<_>>()?;
        for rx in warm_rxs {
            rx.recv()??;
        }
    }
    tracer.set_enabled(true);

    // The traced request mix, round-robin over the model families.
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n {
        let req = demo_request(&router, models[i % models.len()], i, max_new);
        rxs.push(router.submit(req)?);
    }
    let mut responses = Vec::new();
    for rx in rxs {
        responses.push(rx.recv()??);
    }
    let wall = t0.elapsed().as_secs_f64();
    tracer.set_enabled(false);
    let replica_rows = router.replica_reports();
    router.shutdown();

    let trace = tracer.drain();
    chrome_trace::write(std::path::Path::new(&out), &trace)?;
    println!("wrote {} spans to {out} (open in chrome://tracing or \
              ui.perfetto.dev)\n", trace.len());

    let stats = collect_stats(&responses, wall);
    println!("{}\n", stats.report());
    if replicas > 1 {
        println!("== replica routing ({policy}) ==");
        println!("{}\n", render_replica_reports(&replica_rows));
    }
    println!("== measured (traced run) ==");
    let report = TraceReport::from_trace(&trace);
    println!("{}", report.render());

    let dev: &DeviceSpec = DeviceSpec::by_name(&a.get_or("device", "A100"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    println!("== device-model projection (paper scale, baseline) ==");
    println!("{}", render(&standard_breakdown_rows(dev,
                                                   &Levers::baseline())));
    Ok(())
}

/// KV bytes/token for a model family name — the fabric geometry knob.
fn kv_geometry(name: &str) -> Result<f64> {
    Ok(match name {
        "llama-7b" => paper_configs::LLAMA_7B.kv_bytes_per_token(),
        "llama-34b" => paper_configs::LLAMA_34B.kv_bytes_per_token(),
        "chameleon-7b" => paper_configs::CHAMELEON_7B.kv_bytes_per_token(),
        "chameleon-34b" => {
            paper_configs::CHAMELEON_34B.kv_bytes_per_token()
        }
        other => bail!(
            "unknown model family {other:?} (want llama-7b, llama-34b, \
             chameleon-7b or chameleon-34b)"
        ),
    })
}

/// One arm of the disaggregation A/B as a JSON object.
fn disagg_arm_json(r: &RoutingReplayResult) -> Json {
    Json::from_obj(vec![
        ("mean_ttft".into(), Json::Num(r.ttft.mean())),
        ("p99_ttft".into(), Json::Num(r.ttft.percentile(99.0))),
        ("mean_tbt".into(), Json::Num(r.tbt.mean())),
        ("p99_tbt".into(), Json::Num(r.tbt.percentile(99.0))),
        ("completed".into(), Json::Num(r.completed as f64)),
        ("dropped".into(), Json::Num(r.dropped as f64)),
        ("preemptions".into(), Json::Num(r.fleet.preemptions as f64)),
        ("swap_decisions".into(),
         Json::Num(r.fleet.swap_decisions as f64)),
        ("recompute_decisions".into(),
         Json::Num(r.fleet.recompute_decisions as f64)),
        ("transfer_time".into(), Json::Num(r.transfer_time)),
        ("transfer_bytes".into(), Json::Num(r.transfer_bytes as f64)),
        ("link_utilization".into(), Json::Num(r.link_utilization())),
        ("sim_time".into(), Json::Num(r.sim_time)),
    ])
}

/// The `--fabric-json` document (`BENCH_fabric.json` in CI): both arms
/// of the colocated-vs-disaggregated A/B, the headline deltas the gate
/// bounds, and the priced swap-vs-recompute decision mix.
fn fabric_json(rcfg: &RoutingReplayConfig, kv_bytes_per_token: f64,
               colo: &RoutingReplayResult,
               disagg: &RoutingReplayResult) -> Json {
    Json::from_obj(vec![
        ("config".into(), Json::from_obj(vec![
            ("requests".into(), Json::Num(rcfg.base.requests as f64)),
            ("replicas".into(), Json::Num(rcfg.replicas as f64)),
            ("pages".into(), Json::Num(rcfg.base.total_pages as f64)),
            ("slots".into(), Json::Num(rcfg.base.batch_slots as f64)),
            ("tenants".into(), Json::Num(rcfg.base.tenants as f64)),
            ("long_percent".into(),
             Json::Num(rcfg.base.long_percent as f64)),
            ("kv_bytes_per_token".into(), Json::Num(kv_bytes_per_token)),
            ("seed".into(), Json::Num(rcfg.base.seed as f64)),
        ])),
        ("fabric".into(), Json::from_obj(vec![
            ("colocated".into(), disagg_arm_json(colo)),
            ("disaggregated".into(), disagg_arm_json(disagg)),
            ("deltas".into(), Json::from_obj(vec![
                // > 0 when disaggregation wins the decode tail.
                ("p99_tbt_improvement".into(),
                 Json::Num(colo.tbt.percentile(99.0)
                           - disagg.tbt.percentile(99.0))),
                // The explicitly priced TTFT cost of the KV handoff
                // (positive = disaggregated TTFT is worse).
                ("p99_ttft_delta".into(),
                 Json::Num(disagg.ttft.percentile(99.0)
                           - colo.ttft.percentile(99.0))),
            ])),
        ])),
    ])
}

/// Replay metrics of one run as a JSON object (the CI perf artifact).
fn replay_json(r: &ReplayResult) -> Json {
    Json::from_obj(vec![
        ("hit_rate".into(), Json::Num(r.stats.hit_rate())),
        ("prefix_hits".into(), Json::Num(r.stats.prefix_hits as f64)),
        ("prefix_hit_tokens".into(),
         Json::Num(r.stats.prefix_hit_tokens as f64)),
        ("mean_occupancy".into(), Json::Num(r.mean_occupancy)),
        ("mean_pool_utilization".into(),
         Json::Num(r.mean_pool_utilization)),
        ("mean_tbt".into(), Json::Num(r.tbt.mean())),
        ("p99_tbt".into(), Json::Num(r.tbt.percentile(99.0))),
        ("mean_ttft".into(), Json::Num(r.ttft.mean())),
        ("p99_ttft".into(), Json::Num(r.ttft.percentile(99.0))),
        ("completed".into(), Json::Num(r.completed as f64)),
        ("dropped".into(), Json::Num(r.dropped as f64)),
        ("sim_time".into(), Json::Num(r.sim_time)),
        ("shard_spills".into(), Json::Num(r.stats.shard_spills as f64)),
        ("shard_utilization".into(), Json::Arr(
            r.shard_utilization.iter().map(|&u| Json::Num(u)).collect(),
        )),
    ])
}

fn routing_json(r: &RoutingReplayResult) -> Json {
    Json::from_obj(vec![
        ("agg_hit_rate".into(), Json::Num(r.agg_hit_rate())),
        ("prefix_hit_tokens".into(),
         Json::Num(r.fleet.prefix_hit_tokens as f64)),
        ("mean_tbt".into(), Json::Num(r.tbt.mean())),
        ("p99_tbt".into(), Json::Num(r.tbt.percentile(99.0))),
        ("mean_ttft".into(), Json::Num(r.ttft.mean())),
        ("p99_ttft".into(), Json::Num(r.ttft.percentile(99.0))),
        ("completed".into(), Json::Num(r.completed as f64)),
        ("dropped".into(), Json::Num(r.dropped as f64)),
        ("preemptions".into(), Json::Num(r.fleet.preemptions as f64)),
        ("sim_time".into(), Json::Num(r.sim_time)),
        ("routed".into(), Json::Arr(
            r.routed.iter().map(|&c| Json::Num(c as f64)).collect(),
        )),
    ])
}

/// The `--bench-json` document: config echo, single-worker paged vs
/// dense metrics, the sharded run (with `--shards > 1`), and (with
/// `--replicas > 1`) per-policy fleet metrics.
fn bench_json(cfg: &ReplayConfig, paged: &ReplayResult,
              dense: &ReplayResult, sharded: Option<&ReplayResult>,
              shards: usize,
              routing: &[RoutingReplayResult]) -> Json {
    let mut kvpool = vec![
        ("paged".into(), replay_json(paged)),
        ("dense".into(), replay_json(dense)),
    ];
    if let Some(s) = sharded {
        kvpool.push(("sharded".into(), replay_json(s)));
    }
    let mut root = vec![
        ("config".into(), Json::from_obj(vec![
            ("requests".into(), Json::Num(cfg.requests as f64)),
            ("pages".into(), Json::Num(cfg.total_pages as f64)),
            ("page_size".into(), Json::Num(cfg.page_size as f64)),
            ("slots".into(), Json::Num(cfg.batch_slots as f64)),
            ("system_prompt_len".into(),
             Json::Num(cfg.system_prompt_len as f64)),
            ("shards".into(), Json::Num(shards as f64)),
            ("seed".into(), Json::Num(cfg.seed as f64)),
        ])),
        ("kvpool".into(), Json::from_obj(kvpool)),
    ];
    if !routing.is_empty() {
        let policies: Vec<(String, Json)> = routing
            .iter()
            .map(|r| (r.policy.as_str().to_string(), routing_json(r)))
            .collect();
        root.push(("routing".into(), Json::from_obj(vec![
            ("replicas".into(),
             Json::Num(routing[0].replicas as f64)),
            ("shards".into(), Json::Num(shards as f64)),
            ("policies".into(), Json::from_obj(policies)),
        ])));
    }
    Json::from_obj(root)
}

fn cmd_kv(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "kv",
        "replay a mixed workload through the paged KV pool vs dense",
    )
    .opt("requests", "number of replayed requests", Some("64"))
    .opt("pages", "total page budget shared by both runs", Some("96"))
    .opt("page-size", "tokens per KV page", Some("16"))
    .opt("slots", "decode-graph batch for the paged run", Some("16"))
    .opt("max-seq", "sequence capacity (dense slots pin this)",
         Some("512"))
    .opt("system-prompt", "shared system-prompt length (tokens)",
         Some("48"))
    .opt("long-percent", "percent of long-document requests", Some("20"))
    .opt("prefill-budget", "prefill token budget per tick (0 = off)",
         Some("0"))
    .opt("chunk-prefill",
         "chunked prefill: max new prompt tokens per tick (0 = whole)",
         Some("0"))
    .opt("replicas",
         "simulated workers for the routing-policy comparison (1 = off)",
         Some("1"))
    .opt("shards",
         "device arenas the page budget is split across (1 = monolithic)",
         Some("1"))
    .opt("tenants",
         "distinct shared system prompts for the routing comparison",
         Some("4"))
    .opt("bench-json",
         "write replay metrics as JSON to this path (CI perf gate)",
         Some(""))
    .opt("fabric-json",
         "write the disaggregation A/B metrics as JSON (BENCH_fabric)",
         Some(""))
    .opt("model",
         "fabric KV geometry: llama-7b|llama-34b|chameleon-7b|\
          chameleon-34b",
         Some("llama-7b"))
    .opt("mix",
         "mixed fleet: percent per family, e.g. \"seamless:25,hstu:25\" \
          (rest chat; empty = pure chat)",
         Some(""))
    .opt("beam",
         "beam width Seamless replay requests fork per decode tick",
         Some("2"))
    .opt("arrivals",
         "open-loop arrival process: poisson:R or diurnal:BASE:PEAK:T, \
          '+'-joined with burst:AT:LEN:MULT / followups:P / think:T / \
          zipf:S (empty = closed loop, everything queued at t=0)",
         Some(""))
    .opt("autoscale",
         "elastic fleet bounds MIN:MAX for the open-loop replay; A/Bs \
          the autoscaler against fixed fleets pinned at MIN and MAX \
          (requires --arrivals)",
         Some(""))
    .opt("autoscale-json",
         "write the autoscale A/B metrics as JSON (BENCH_autoscale)",
         Some(""))
    .opt("seed", "workload seed", Some("7"))
    .opt("device", "A100|H100 for the Table-3 projection", Some("A100"))
    .flag("disaggregate",
          "A/B colocated vs disaggregated prefill/decode over the \
           priced fabric (uses --replicas, min 2)")
    .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let chunk = a.get_usize("chunk-prefill", 0);
    let mix = parse_mix(&a)?;
    let cfg = ReplayConfig {
        requests: a.get_usize("requests", 64),
        system_prompt_len: a.get_usize("system-prompt", 48),
        long_percent: a.get_usize("long-percent", 20),
        page_size: a.get_usize("page-size", 16).max(1),
        total_pages: a.get_usize("pages", 96).max(1),
        batch_slots: a.get_usize("slots", 16).max(1),
        max_seq: a.get_usize("max-seq", 512),
        prefill_budget: a.get_usize("prefill-budget", 0),
        seed: a.get_usize("seed", 7) as u64,
        mix,
        arrivals: parse_arrivals(&a)?,
        ..ReplayConfig::default()
    };
    let autoscale = parse_autoscale(&a.get_or("autoscale", ""))?;
    if autoscale.is_some() && cfg.arrivals.is_none() {
        bail!("--autoscale needs an open-loop stream; pass --arrivals");
    }
    let replicas = a.get_usize("replicas", 1).max(1);
    let shards = a.get_usize("shards", 1).max(1);
    println!(
        "== kvpool replay: {} requests, {}% long, {} shared system-prompt \
         tokens ==",
        cfg.requests, cfg.long_percent, cfg.system_prompt_len
    );
    println!(
        "budget: {} pages × {} tokens = {} KV token slots \
         (dense equivalent: {} full-length slots)\n",
        cfg.total_pages,
        cfg.page_size,
        cfg.total_pages * cfg.page_size,
        cfg.dense_slots()
    );
    // `paged` stays the monolithic (1-arena) run so its metrics remain
    // comparable release over release; `--shards D` adds a sharded run
    // next to it below.
    let paged = replay(&cfg, true);
    let dense = replay(&cfg, false);
    println!("{}", render_comparison(&paged, &dense));
    // Per-pool counters are exactly that — one worker's. The header
    // says so (fleet-wide numbers come from the routing section's
    // summed aggregate below).
    println!("\n== pool counters (single worker, this replay only) ==");
    println!("{}", paged.stats.render());

    // Mixed fleet: chat + Seamless (beam fork/prune) + HSTU
    // (prefill-only) through the same scheduler and pool, with the
    // paper's per-modality latency/attribution lens.
    if mix.is_some() {
        println!(
            "\n== mixed fleet: per-modality latency and attribution \
             (simulated clock) =="
        );
        println!("{}", render_family_table(&paged));
    }

    // Sharded run: the same budget split across `--shards` device
    // arenas — per-shard occupancy, spills, and the capacity parity
    // with the monolithic arena.
    let mut sharded: Option<ReplayResult> = None;
    if shards > 1 {
        let s = replay(&ReplayConfig { shards, ..cfg.clone() }, true);
        println!(
            "\n== sharded pool: same {} pages across {shards} device \
             arenas ==",
            cfg.total_pages
        );
        println!("{}", render_shard_comparison(&paged, &s, shards));
        sharded = Some(s);
    }

    if chunk > 0 {
        // Same mix, chunked admission: the prefill/decode-interference
        // comparison on the simulated clock.
        let chunked =
            replay(&ReplayConfig { chunk_prefill: chunk, ..cfg.clone() },
                   true);
        println!(
            "\n== chunked prefill ({chunk} tokens/tick) vs whole-prompt \
             admission (simulated clock) =="
        );
        println!("{}", render_chunk_comparison(&paged, &chunked, chunk));
    }

    // Replicated workers: the routing-policy comparison. Each policy
    // replays the identical multi-tenant workload over N simulated
    // workers (each with its own page budget).
    let mut routing_results: Vec<RoutingReplayResult> = Vec::new();
    if replicas > 1 {
        let rcfg = RoutingReplayConfig {
            base: ReplayConfig {
                tenants: a.get_usize("tenants", 4).max(1),
                shards,
                ..cfg.clone()
            },
            replicas,
            ..RoutingReplayConfig::default()
        };
        routing_results = compare_policies(&rcfg);
        println!(
            "\n== replica routing: {} workers ({} shards each), {} \
             tenants, per-policy (simulated clock) ==",
            replicas, shards, rcfg.base.tenants
        );
        println!("{}", render_policy_comparison(&routing_results));
        let affinity = routing_results
            .iter()
            .find(|r| r.policy == RoutingPolicy::PrefixAffinity)
            .expect("prefix-affinity result");
        println!(
            "\n== per-worker pool counters under prefix-affinity \
             (fleet rates from summed counters) =="
        );
        println!("{}", render_worker_counters(affinity));
    }

    // Open-loop arrivals: requests land on the fleet when the rate
    // curve says so instead of being pre-queued at t=0. With
    // `--autoscale MIN:MAX` an elastic fleet chases the curve and is
    // A/B'd against fixed fleets pinned at MIN and at MAX.
    if let Some(spec) = cfg.arrivals.clone() {
        let acfg = AutoscaleReplayConfig {
            base: ReplayConfig {
                tenants: a.get_usize("tenants", 4).max(1),
                shards,
                ..cfg.clone()
            },
            policy: RoutingPolicy::LeastLoaded,
            replicas,
            autoscale,
            ..AutoscaleReplayConfig::default()
        };
        match autoscale {
            None => {
                let r = autoscale_replay(&acfg);
                println!(
                    "\n== open-loop replay: {spec} over a fixed fleet \
                     of {replicas} (least-loaded, simulated clock) =="
                );
                println!(
                    "arrivals {}  completed {}  dropped {}  p50 TTFT \
                     {:.2}  p99 TTFT {:.2}  sim time {:.1}",
                    r.arrivals,
                    r.completed,
                    r.dropped,
                    r.ttft.percentile(50.0),
                    r.ttft.percentile(99.0),
                    r.sim_time
                );
                println!("\n== TTFT by arrival phase ==");
                println!("{}", render_phase_ttft(&r));
            }
            Some(sc) => {
                let c = compare_autoscale(&acfg);
                println!(
                    "\n== autoscaled open-loop replay: {spec}, elastic \
                     fleet {}..{} vs fixed min/max (least-loaded, \
                     simulated clock) ==",
                    sc.min, sc.max
                );
                println!("{}", render_autoscale_comparison(&c));
                println!("\n== scale-event timeline (autoscaled) ==");
                println!("{}", render_scale_timeline(&c.autoscaled));
                println!("\n== TTFT by arrival phase (autoscaled) ==");
                println!("{}", render_phase_ttft(&c.autoscaled));
                let as_path = a.get_or("autoscale-json", "");
                if !as_path.is_empty() {
                    let json = autoscale_json(&acfg, &spec, &sc, &c);
                    std::fs::write(&as_path, json.to_string())?;
                    println!("wrote autoscale A/B metrics to {as_path}");
                }
            }
        }
    }

    // Disaggregated prefill/decode A/B over the priced fabric: the
    // identical workload once colocated, once split (first half of the
    // fleet prefills and ships KV over the network link, second half
    // decodes) — the decode-tail-vs-handoff-TTFT tradeoff.
    if a.flag("disaggregate") {
        let kv_bytes = kv_geometry(&a.get_or("model", "llama-7b"))?;
        let rcfg = RoutingReplayConfig {
            base: ReplayConfig {
                tenants: a.get_usize("tenants", 4).max(1),
                shards,
                fabric: Some(FabricSpec::paper(kv_bytes)),
                ..cfg.clone()
            },
            replicas: replicas.max(2),
            ..RoutingReplayConfig::default()
        };
        let (colo, disagg) =
            compare_disaggregation(&rcfg, RoutingPolicy::LeastLoaded);
        println!(
            "\n== disaggregated prefill/decode vs colocated ({} workers, \
             least-loaded, simulated clock) ==",
            rcfg.replicas
        );
        println!("{}", render_disagg_comparison(&colo, &disagg));
        let fabric_path = a.get_or("fabric-json", "");
        if !fabric_path.is_empty() {
            let json = fabric_json(&rcfg, kv_bytes, &colo, &disagg);
            std::fs::write(&fabric_path, json.to_string())?;
            println!("wrote fabric A/B metrics to {fabric_path}");
        }
    }

    let json_path = a.get_or("bench-json", "");
    if !json_path.is_empty() {
        let json = bench_json(&cfg, &paged, &dense, sharded.as_ref(),
                              shards, &routing_results);
        std::fs::write(&json_path, json.to_string())?;
        println!("\nwrote replay metrics to {json_path}");
    }

    let dev: &DeviceSpec = DeviceSpec::by_name(&a.get_or("device", "A100"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    println!(
        "\n== Table-3 projection on {}: achievable batch, dense vs \
         paged (page {} tokens) ==",
        dev.name, cfg.page_size
    );
    let mut t = mmserve::substrate::table::Table::new(
        &["task", "dense batch", "paged batch"],
    );
    for row in mmserve::workload::batchcfg::paged_vs_dense_rows(
        dev, cfg.page_size,
    ) {
        t.row(&[
            format!("{}", row.task),
            row.dense.to_string(),
            row.paged.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// `--mix "seamless:25,hstu:25" --beam B`: the mixed-fleet selector
/// shared by `kv`, `stats`, and `explain` (empty `--mix` = pure chat).
fn parse_mix(a: &mmserve::substrate::cli::Args)
             -> Result<Option<MixSpec>> {
    let spec = a.get_or("mix", "");
    if spec.is_empty() {
        return Ok(None);
    }
    let beam = a.get_usize("beam", 2);
    Ok(Some(MixSpec::parse(&spec, beam).map_err(anyhow::Error::msg)?))
}

/// `--kill R@K`: crash replica R after K requests were delivered.
fn parse_kill(spec: &str) -> Result<Option<KillSpec>> {
    if spec.is_empty() {
        return Ok(None);
    }
    let (r, k) = spec.split_once('@').ok_or_else(|| {
        anyhow::anyhow!("--kill wants R@K (replica@delivered), got {spec:?}")
    })?;
    Ok(Some(KillSpec {
        replica: r.trim().parse()?,
        after_delivered: k.trim().parse()?,
    }))
}

/// `--arrivals SPEC`: the open-loop arrival process (empty = the
/// historical closed loop, every request queued at t=0).
fn parse_arrivals(a: &mmserve::substrate::cli::Args)
                  -> Result<Option<ArrivalSpec>> {
    let spec = a.get_or("arrivals", "");
    if spec.is_empty() {
        return Ok(None);
    }
    Ok(Some(ArrivalSpec::parse(&spec).map_err(anyhow::Error::msg)?))
}

/// `--autoscale MIN:MAX`: elastic fleet bounds (empty = fixed fleet).
fn parse_autoscale(spec: &str) -> Result<Option<AutoscaleSpec>> {
    if spec.is_empty() {
        return Ok(None);
    }
    Ok(Some(AutoscaleSpec::parse(spec).map_err(anyhow::Error::msg)?))
}

/// One arm of the autoscale A/B as a JSON object (the CI artifact).
fn autoscale_arm_json(r: &AutoscaleReplayResult) -> Json {
    Json::from_obj(vec![
        ("p50_ttft".into(), Json::Num(r.ttft.percentile(50.0))),
        ("p99_ttft".into(), Json::Num(r.ttft.percentile(99.0))),
        ("burst_p99_ttft".into(),
         Json::Num(r.phase_p99(ArrivalPhase::Burst))),
        ("goodput_per_replica".into(),
         Json::Num(r.goodput_per_replica())),
        ("replica_seconds".into(), Json::Num(r.replica_seconds)),
        ("peak_replicas".into(), Json::Num(r.peak_replicas as f64)),
        ("arrivals".into(), Json::Num(r.arrivals as f64)),
        ("completed".into(), Json::Num(r.completed as f64)),
        ("dropped".into(), Json::Num(r.dropped as f64)),
        ("scale_ups".into(), Json::Num(r.scale_ups() as f64)),
        ("drains".into(), Json::Num(r.drains() as f64)),
        ("sim_time".into(), Json::Num(r.sim_time)),
    ])
}

/// The `--autoscale-json` document (BENCH_autoscale): config echo,
/// the three arms, and the headline deltas the CI gate checks
/// (autoscaled must beat the fixed-min fleet on burst tail latency
/// and the fixed-max fleet on paid replica-seconds).
fn autoscale_json(cfg: &AutoscaleReplayConfig, spec: &ArrivalSpec,
                  sc: &AutoscaleSpec,
                  c: &AutoscaleComparison) -> Json {
    let auto_ = &c.autoscaled;
    let min_ = &c.fixed_min;
    let max_ = &c.fixed_max;
    let goodput_ratio = if max_.goodput_per_replica() > 0.0 {
        auto_.goodput_per_replica() / max_.goodput_per_replica()
    } else {
        1.0
    };
    Json::from_obj(vec![
        ("config".into(), Json::from_obj(vec![
            ("requests".into(), Json::Num(cfg.base.requests as f64)),
            ("tenants".into(), Json::Num(cfg.base.tenants as f64)),
            ("shards".into(), Json::Num(cfg.base.shards as f64)),
            ("seed".into(), Json::Num(cfg.base.seed as f64)),
            ("arrivals".into(), Json::Str(spec.to_string())),
            ("min".into(), Json::Num(sc.min as f64)),
            ("max".into(), Json::Num(sc.max as f64)),
            ("policy".into(),
             Json::Str(cfg.policy.as_str().to_string())),
        ])),
        ("autoscale".into(), Json::from_obj(vec![
            ("autoscaled".into(), autoscale_arm_json(auto_)),
            ("fixed_min".into(), autoscale_arm_json(min_)),
            ("fixed_max".into(), autoscale_arm_json(max_)),
            ("deltas".into(), Json::from_obj(vec![
                // > 0 when the elastic fleet absorbs the burst better
                // than the fleet pinned at MIN.
                ("burst_p99_ttft_improvement".into(),
                 Json::Num(min_.phase_p99(ArrivalPhase::Burst)
                           - auto_.phase_p99(ArrivalPhase::Burst))),
                // > 0 when it pays less capacity than the fleet
                // pinned at MAX.
                ("replica_seconds_saved".into(),
                 Json::Num(max_.replica_seconds
                           - auto_.replica_seconds)),
                // Efficiency guard: elastic goodput per replica-second
                // must stay within tolerance of the fixed-max fleet.
                ("goodput_ratio_vs_max".into(),
                 Json::Num(goodput_ratio)),
            ])),
        ])),
    ])
}

/// A percentile cell: "-" for an empty sketch (e.g. a crashed replica
/// that never finished a prefill).
fn pct_cell(s: &SketchSnapshot, p: f64) -> String {
    if s.is_empty() {
        "-".into()
    } else {
        format!("{:.2}", s.percentile(p))
    }
}

fn cmd_stats(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "stats",
        "replay a fleet workload with the live metrics plane attached; \
         render the per-replica / per-shard / per-tenant dashboard",
    )
    .opt("requests", "number of replayed requests", Some("96"))
    .opt("replicas", "simulated workers (each owns a page budget)",
         Some("3"))
    .opt("shards",
         "device arenas each worker's page budget is split across",
         Some("2"))
    .opt("tenants", "distinct shared system prompts", Some("3"))
    .opt("policy",
         "replica routing: round-robin|least-loaded|prefix-affinity",
         Some("prefix-affinity"))
    .opt("pages", "page budget per worker", Some("96"))
    .opt("page-size", "tokens per KV page", Some("16"))
    .opt("slots", "decode-graph batch per worker", Some("16"))
    .opt("chunk-prefill",
         "chunked prefill: max new prompt tokens per tick (0 = whole)",
         Some("0"))
    .opt("mix",
         "mixed fleet: percent per family, e.g. \"seamless:25,hstu:25\" \
          (rest chat; empty = pure chat)",
         Some(""))
    .opt("beam",
         "beam width Seamless replay requests fork per decode tick",
         Some("2"))
    .opt("kill",
         "crash injection R@K: kill replica R after K deliveries",
         Some(""))
    .opt("metrics-out",
         "write the Prometheus text exposition to this path", Some(""))
    .opt("record-out",
         "write flight-recorder JSONL dumps to this path", Some(""))
    .opt("bench-json",
         "write live-plane cost/parity metrics as JSON (CI perf gate)",
         Some(""))
    .opt("seed", "workload seed", Some("7"))
    .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let replicas = a.get_usize("replicas", 3).max(1);
    let shards = a.get_usize("shards", 2).max(1);
    let policy = parse_policy(&a)?;
    let kill = parse_kill(&a.get_or("kill", ""))?;
    let mix = parse_mix(&a)?;
    let rcfg = RoutingReplayConfig {
        base: ReplayConfig {
            requests: a.get_usize("requests", 96),
            page_size: a.get_usize("page-size", 16).max(1),
            total_pages: a.get_usize("pages", 96).max(1),
            batch_slots: a.get_usize("slots", 16).max(1),
            chunk_prefill: a.get_usize("chunk-prefill", 0),
            tenants: a.get_usize("tenants", 3).max(1),
            shards,
            seed: a.get_usize("seed", 7) as u64,
            mix,
            ..ReplayConfig::default()
        },
        replicas,
        kill,
        ..RoutingReplayConfig::default()
    };

    let live = LiveMetrics::new();
    let recorder = FlightRecorder::new(256);
    let t_live = std::time::Instant::now();
    let r = routing_replay_live(&rcfg, policy, &live, &recorder);
    let wall_live = t_live.elapsed();
    let snap = live.snapshot();

    println!(
        "== live fleet dashboard: {replicas} replicas × {shards} \
         shards, {} tenants, {policy} (simulated clock units) ==",
        rcfg.base.tenants
    );
    println!(
        "completed {} / dropped {} in sim_time {:.1}\n",
        r.completed, r.dropped, r.sim_time
    );

    let mut tr = Table::new(&[
        "replica", "routed", "ticks", "done", "tokens", "queue",
        "hit rate", "waits", "preempt", "spills", "ttft p50",
        "ttft p99", "tbt p50", "tbt p99",
    ]);
    for i in 0..replicas {
        let rs = i.to_string();
        let l = [("replica", rs.as_str())];
        let cnt =
            |name: &str| snap.counter(name, &l).unwrap_or(0).to_string();
        let ttft = snap.merged_sketch(TTFT_MS, "replica", &rs);
        let tbt = snap.merged_sketch(TBT_MS, "replica", &rs);
        tr.row(&[
            rs.clone(),
            r.routed.get(i).copied().unwrap_or(0).to_string(),
            cnt(TICKS_TOTAL),
            cnt(REQUESTS_COMPLETED_TOTAL),
            cnt(TOKENS_DECODED_TOTAL),
            format!("{:.0}", snap.gauge(QUEUE_DEPTH, &l).unwrap_or(0.0)),
            format!("{:.3}",
                    snap.gauge(PREFIX_HIT_RATE, &l).unwrap_or(0.0)),
            cnt(CAPACITY_WAIT_TICKS_TOTAL),
            cnt(PREEMPTIONS_TOTAL),
            cnt(SHARD_SPILLS_TOTAL),
            pct_cell(&ttft, 50.0),
            pct_cell(&ttft, 99.0),
            pct_cell(&tbt, 50.0),
            pct_cell(&tbt, 99.0),
        ]);
    }
    println!("per-replica:\n{}", tr.render());

    let mut ts = Table::new(&[
        "replica", "shard", "live pages", "free pages", "cached pages",
    ]);
    for i in 0..replicas {
        for s in 0..shards {
            let (rs, ss) = (i.to_string(), s.to_string());
            let l = [("replica", rs.as_str()), ("shard", ss.as_str())];
            let Some(lp) = snap.gauge(LIVE_PAGES, &l) else {
                continue;
            };
            ts.row(&[
                rs.clone(),
                ss,
                format!("{lp:.0}"),
                format!("{:.0}", snap.gauge(FREE_PAGES, &l).unwrap_or(0.0)),
                format!("{:.0}",
                        snap.gauge(CACHED_PAGES, &l).unwrap_or(0.0)),
            ]);
        }
    }
    println!("\nper-shard pages (point-in-time, end of run):\n{}",
             ts.render());

    // Per-tenant energy attribution: the identical seeded replay with
    // the causal ledger attached. Run separately from the live replay
    // so the sampler cost metric below stays a pure live-plane
    // measure (observation never changes the simulated outcome).
    let energy = EnergyModel::by_device_name(ModelFamily::Llama7b, "A100")
        .expect("A100 device spec");
    let ledger = RequestLedger::new();
    let _ = routing_replay_instrumented(&rcfg, policy, &LiveMetrics::off(),
                                        &FlightRecorder::disabled(),
                                        &ledger);
    let tenant_energy: std::collections::HashMap<String, EnergyBreakdown> =
        energy.energy_by_tenant(&ledger.snapshot()).into_iter().collect();

    // In a mixed fleet the sketch/ledger cohort label carries the
    // model family instead of the tenant id, so the same table (and
    // the energy attribution behind it) becomes per-modality.
    let who = if mix.is_some() { "family" } else { "tenant" };
    let mut tt = Table::new(&[
        who, "requests", "ttft p50", "ttft p99", "tbt p50",
        "tbt p99", "energy J", "tok/J",
    ]);
    for tenant in snap.sketch_label_values(TTFT_MS, "tenant") {
        let ttft = snap.merged_sketch(TTFT_MS, "tenant", &tenant);
        let tbt = snap.merged_sketch(TBT_MS, "tenant", &tenant);
        let e = tenant_energy.get(&tenant);
        tt.row(&[
            tenant.clone(),
            ttft.count.to_string(),
            pct_cell(&ttft, 50.0),
            pct_cell(&ttft, 99.0),
            pct_cell(&tbt, 50.0),
            pct_cell(&tbt, 99.0),
            e.map(|e| format!("{:.1}", e.total_j()))
                .unwrap_or_else(|| "-".into()),
            e.map(|e| format!("{:.1}", e.tokens_per_joule()))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    println!(
        "\nper-{who} SLO percentiles + modeled energy ({} on {}):\n{}",
        energy.family.as_str(),
        energy.device.name,
        tt.render()
    );

    // Streaming sketches vs the post-hoc histograms the replay kept:
    // they must agree within the sketch's relative error.
    let mut all_ttft = SketchSnapshot::empty();
    let mut all_tbt = SketchSnapshot::empty();
    for rv in snap.sketch_label_values(TTFT_MS, "replica") {
        all_ttft.merge(&snap.merged_sketch(TTFT_MS, "replica", &rv));
    }
    for rv in snap.sketch_label_values(TBT_MS, "replica") {
        all_tbt.merge(&snap.merged_sketch(TBT_MS, "replica", &rv));
    }
    println!(
        "\nstreaming vs post-hoc: ttft p99 {:.2} / {:.2}, \
         tbt p99 {:.2} / {:.2}",
        all_ttft.percentile(99.0),
        r.ttft.percentile(99.0),
        all_tbt.percentile(99.0),
        r.tbt.percentile(99.0)
    );

    let dumps = recorder.dumps();
    if !dumps.is_empty() {
        let reasons: Vec<&str> =
            dumps.iter().map(|d| d.reason.as_str()).collect();
        println!("flight recorder: {} dump(s): {}", dumps.len(),
                 reasons.join(", "));
    }
    let rec_path = a.get_or("record-out", "");
    if !rec_path.is_empty() {
        let mut out = String::new();
        for d in &dumps {
            out.push_str(&d.jsonl);
            if !d.jsonl.ends_with('\n') {
                out.push('\n');
            }
        }
        std::fs::write(&rec_path, out)?;
        println!("wrote flight-recorder dumps to {rec_path}");
    }
    let metrics_path = a.get_or("metrics-out", "");
    if !metrics_path.is_empty() {
        prometheus::write_file(&snap, std::path::Path::new(&metrics_path))?;
        println!("wrote Prometheus exposition to {metrics_path}");
    }
    let json_path = a.get_or("bench-json", "");
    if !json_path.is_empty() {
        // Sampler cost + pure-observation parity: the identical
        // seeded replay without the live plane. The simulated clocks
        // must agree exactly (observation never changes scheduling);
        // the wall-clock delta per published tick is the sampler's
        // hot-path cost.
        let t_bare = std::time::Instant::now();
        let bare = mmserve::routing::replay::routing_replay(&rcfg,
                                                            policy);
        let wall_bare = t_bare.elapsed();
        let ticks: u64 = snap
            .counters
            .iter()
            .filter(|(s, _)| s.name == TICKS_TOTAL)
            .map(|(_, v)| v)
            .sum();
        let ns_per_tick = wall_live.saturating_sub(wall_bare)
            .as_nanos() as f64
            / ticks.max(1) as f64;
        let json = Json::from_obj(vec![
            ("config".into(), Json::from_obj(vec![
                ("requests".into(),
                 Json::Num(rcfg.base.requests as f64)),
                ("replicas".into(), Json::Num(replicas as f64)),
                ("shards".into(), Json::Num(shards as f64)),
                ("tenants".into(),
                 Json::Num(rcfg.base.tenants as f64)),
                ("seed".into(), Json::Num(rcfg.base.seed as f64)),
            ])),
            ("live".into(), Json::from_obj(vec![
                ("ticks".into(), Json::Num(ticks as f64)),
                ("completed".into(), Json::Num(r.completed as f64)),
                ("sim_time".into(), Json::Num(r.sim_time)),
                ("sim_time_delta".into(),
                 Json::Num((r.sim_time - bare.sim_time).abs())),
                ("sampler_ns_per_tick".into(), Json::Num(ns_per_tick)),
            ])),
        ]);
        std::fs::write(&json_path, json.to_string())?;
        println!("wrote live-plane metrics to {json_path}");
    }
    Ok(())
}

fn cmd_explain(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "explain",
        "replay a fleet workload with the per-request causal cost \
         ledger attached; explain tail latency and attribute Joules",
    )
    .opt("requests", "number of replayed requests", Some("96"))
    .opt("replicas", "simulated workers (each owns a page budget)",
         Some("3"))
    .opt("shards",
         "device arenas each worker's page budget is split across",
         Some("2"))
    .opt("tenants", "distinct shared system prompts", Some("3"))
    .opt("policy",
         "replica routing: round-robin|least-loaded|prefix-affinity",
         Some("prefix-affinity"))
    .opt("pages", "page budget per worker", Some("96"))
    .opt("page-size", "tokens per KV page", Some("16"))
    .opt("slots", "decode-graph batch per worker", Some("16"))
    .opt("chunk-prefill",
         "chunked prefill: max new prompt tokens per tick (0 = whole)",
         Some("0"))
    .opt("mix",
         "mixed fleet: percent per family, e.g. \"seamless:25,hstu:25\" \
          (rest chat; empty = pure chat)",
         Some(""))
    .opt("beam",
         "beam width Seamless replay requests fork per decode tick",
         Some("2"))
    .opt("kill",
         "crash injection R@K: kill replica R after K deliveries",
         Some(""))
    .opt("request",
         "explain one request id: causal timeline + Joule attribution",
         Some(""))
    .opt("slowest", "explain the K slowest requests (0 = use --tail)",
         Some("0"))
    .opt("tail",
         "explain the latency tail at this quantile (p99, p95, ...)",
         Some("p99"))
    .opt("model",
         "energy-model family: llama-7b|llama-34b|chameleon-7b|\
          chameleon-34b",
         Some("llama-7b"))
    .opt("device", "energy-model device: A100|H100", Some("A100"))
    .opt("ledger-out",
         "write the per-request ledger JSONL to this path", Some(""))
    .opt("bench-json",
         "write ledger cost + tokens-per-Joule JSON (CI perf gate)",
         Some(""))
    .opt("seed", "workload seed", Some("7"))
    .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let replicas = a.get_usize("replicas", 3).max(1);
    let shards = a.get_usize("shards", 2).max(1);
    let policy = parse_policy(&a)?;
    let kill = parse_kill(&a.get_or("kill", ""))?;
    let family = ModelFamily::parse(&a.get_or("model", "llama-7b"))
        .ok_or_else(|| {
            anyhow::anyhow!("unknown model family (want llama-7b, \
                             llama-34b, chameleon-7b or chameleon-34b)")
        })?;
    let energy =
        EnergyModel::by_device_name(family, &a.get_or("device", "A100"))
            .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let rcfg = RoutingReplayConfig {
        base: ReplayConfig {
            requests: a.get_usize("requests", 96),
            page_size: a.get_usize("page-size", 16).max(1),
            total_pages: a.get_usize("pages", 96).max(1),
            batch_slots: a.get_usize("slots", 16).max(1),
            chunk_prefill: a.get_usize("chunk-prefill", 0),
            tenants: a.get_usize("tenants", 3).max(1),
            shards,
            seed: a.get_usize("seed", 7) as u64,
            mix: parse_mix(&a)?,
            ..ReplayConfig::default()
        },
        replicas,
        kill,
        ..RoutingReplayConfig::default()
    };

    // Ledger-attached replay. The live plane and flight recorder stay
    // disabled: this command measures the ledger's own cost.
    let live = LiveMetrics::off();
    let recorder = FlightRecorder::disabled();
    let ledger = RequestLedger::new();
    let t_led = std::time::Instant::now();
    let r = routing_replay_instrumented(&rcfg, policy, &live, &recorder,
                                        &ledger);
    let wall_ledger = t_led.elapsed();
    let snap = ledger.snapshot();

    println!(
        "== causal cost ledger: {} requests over {replicas} replicas × \
         {shards} shards, {policy} (simulated clock units) ==",
        rcfg.base.requests
    );
    println!(
        "completed {} / dropped {} in sim_time {:.1}; ledger tracked \
         {} requests\n",
        r.completed, r.dropped, r.sim_time, snap.requests.len()
    );

    let req_spec = a.get_or("request", "");
    let slowest = a.get_usize("slowest", 0);
    if !req_spec.is_empty() {
        let id: u64 = req_spec.parse()?;
        let Some(rec) = snap.get(id) else {
            bail!("request {id} is not in the ledger (this replay \
                   delivered ids 0..{})", rcfg.base.requests);
        };
        println!("{}", render_request(rec, Some(&energy)));
    } else if slowest > 0 {
        let rows = slowest_rows(&snap, slowest);
        println!("{}", render_rows(&format!("slowest {slowest}"), &rows));
    } else {
        let spec = a.get_or("tail", "p99");
        let p = parse_tail(&spec).ok_or_else(|| {
            anyhow::anyhow!("--tail wants pNN (e.g. p99), got {spec:?}")
        })?;
        let rows = tail_rows(&snap, p);
        println!("{}",
                 render_rows(&format!("latency tail at {spec}"), &rows));
    }

    let fleet = energy.fleet_energy(&snap);
    println!(
        "\nfleet energy ({} on {}): prefill {:.1} J + decode {:.1} J + \
         idle {:.1} J = {:.1} J over {} tokens ({:.1} tok/J)",
        family.as_str(),
        energy.device.name,
        fleet.prefill_j,
        fleet.decode_j,
        fleet.idle_j,
        fleet.total_j(),
        fleet.tokens,
        fleet.tokens_per_joule()
    );

    let ledger_path = a.get_or("ledger-out", "");
    if !ledger_path.is_empty() {
        std::fs::write(&ledger_path, snap.to_jsonl())?;
        println!("wrote per-request ledger JSONL to {ledger_path}");
    }

    let json_path = a.get_or("bench-json", "");
    if !json_path.is_empty() {
        // Ledger cost + pure-observation parity: the identical seeded
        // replay bare (the clocks must agree exactly), and once more
        // with a disabled ledger attached — the one-relaxed-load
        // regime the CI perf gate bounds below 250 ns/tick.
        let t_bare = std::time::Instant::now();
        let bare = routing_replay(&rcfg, policy);
        let wall_bare = t_bare.elapsed();
        let off = RequestLedger::off();
        let t_off = std::time::Instant::now();
        let _ = routing_replay_instrumented(&rcfg, policy, &live,
                                            &recorder, &off);
        let wall_off = t_off.elapsed();
        let ticks = r.ticks.max(1) as f64;
        let ns_per_tick = wall_ledger.saturating_sub(wall_bare)
            .as_nanos() as f64
            / ticks;
        let disabled_ns_per_tick = wall_off.saturating_sub(wall_bare)
            .as_nanos() as f64
            / ticks;
        let tpj: Vec<(String, Json)> = ModelFamily::ALL
            .iter()
            .map(|f| {
                let m = EnergyModel::new(*f, energy.device);
                (f.as_str().to_string(),
                 Json::Num(m.fleet_energy(&snap).tokens_per_joule()))
            })
            .collect();
        let json = Json::from_obj(vec![
            ("config".into(), Json::from_obj(vec![
                ("requests".into(),
                 Json::Num(rcfg.base.requests as f64)),
                ("replicas".into(), Json::Num(replicas as f64)),
                ("device".into(),
                 Json::Str(energy.device.name.to_string())),
                ("seed".into(), Json::Num(rcfg.base.seed as f64)),
            ])),
            ("ledger".into(), Json::from_obj(vec![
                ("ticks".into(), Json::Num(r.ticks as f64)),
                ("completed".into(), Json::Num(r.completed as f64)),
                ("sim_time".into(), Json::Num(r.sim_time)),
                ("sim_time_delta".into(),
                 Json::Num((r.sim_time - bare.sim_time).abs())),
                ("ns_per_tick".into(), Json::Num(ns_per_tick)),
                ("disabled_ns_per_tick".into(),
                 Json::Num(disabled_ns_per_tick)),
                ("tokens_per_joule".into(), Json::from_obj(tpj)),
            ])),
        ]);
        std::fs::write(&json_path, json.to_string())?;
        println!("wrote ledger metrics to {json_path}");
    }
    Ok(())
}
