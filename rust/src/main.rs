//! mmserve CLI — leader entrypoint.
//!
//! Subcommands:
//! * `serve`        — start the multi-model router and run a demo batch
//!                    of requests against it (in-process client).
//! * `characterize` — print the paper's Figure-4-style operator
//!                    breakdown from the analytical device model.
//! * `autoquant`    — run the §4.2 quantization calibration on real
//!                    executables.
//! * `stages`       — list AOT stages available per model.

use anyhow::{bail, Result};

use mmserve::coordinator::autoquant;
use mmserve::coordinator::opts::{AttnImpl, ExecMode, OptConfig, QuantMode};
use mmserve::coordinator::request::{Request, SamplingParams};
use mmserve::coordinator::seamless_pipe::ReorderMode;
use mmserve::coordinator::server::{collect_stats, Router, RouterConfig};
use mmserve::models::{ModelKind, TaskKind};
use mmserve::perfmodel::breakdown::render;
use mmserve::perfmodel::device::DeviceSpec;
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::standard_breakdown_rows;
use mmserve::runtime::engine::Engine;
use mmserve::substrate::cli::Command;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("mmserve: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "mmserve <serve|characterize|autoquant|stages> [options]\n\
     run `mmserve <cmd> --help` for command options"
        .to_string()
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "characterize" => cmd_characterize(rest),
        "autoquant" => cmd_autoquant(rest),
        "stages" => cmd_stages(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn opt_from_args(a: &mmserve::substrate::cli::Args) -> OptConfig {
    let mut opt = OptConfig::baseline();
    if a.flag("sdpa") {
        opt.attn = AttnImpl::Flash;
    }
    if a.flag("eager") {
        opt.exec = ExecMode::Eager;
    }
    match a.get_or("quant", "f32").as_str() {
        "int8wo" => opt.quant = QuantMode::Int8WeightOnly,
        "int8dyn" => opt.quant = QuantMode::Int8Dynamic,
        _ => {}
    }
    if a.flag("layerskip") {
        opt.layerskip = true;
    }
    opt
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "serve a demo request batch")
        .opt("models", "comma list of models", Some("llama"))
        .opt("requests", "number of demo requests", Some("8"))
        .opt("max-new", "max new tokens per request", Some("16"))
        .opt("batch", "decode batch size", Some("4"))
        .opt("quant", "f32|int8wo|int8dyn", Some("f32"))
        .flag("sdpa", "enable the flash-attention stages")
        .flag("eager", "per-op dispatch (launch-overhead baseline)")
        .flag("layerskip", "self-speculative decoding")
        .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let models: Vec<ModelKind> = a
        .get_or("models", "llama")
        .split(',')
        .filter_map(ModelKind::parse)
        .collect();
    if models.is_empty() {
        bail!("no valid models given");
    }
    let opt = opt_from_args(&a);
    let n = a.get_usize("requests", 8);
    let max_new = a.get_usize("max-new", 16);

    println!("starting router: models={models:?} opt=[{opt}]");
    let router = Router::start(
        &mmserve::artifacts_dir(),
        RouterConfig {
            models: models.clone(),
            opt,
            reorder: ReorderMode::Fused,
            batch: a.get_usize("batch", 4),
            prefill_budget: 0,
        },
    );

    let prompts = [
        "write a function to reverse a string",
        "def fib(n): compute the fibonacci numbers",
        "explain the borrow checker",
        "sort a list of integers in rust",
    ];
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n {
        let mut req = Request::text(
            router.fresh_id(),
            TaskKind::TextToText,
            prompts[i % prompts.len()],
            max_new,
        );
        req.sampling = SamplingParams::greedy();
        rxs.push(router.submit(req)?);
    }
    let mut responses = Vec::new();
    for rx in rxs {
        responses.push(rx.recv()??);
    }
    let stats = collect_stats(&responses, t0.elapsed().as_secs_f64());
    println!("{}", stats.report());
    for r in responses.iter().take(2) {
        if let mmserve::coordinator::request::ResponseOutput::Text(t) =
            &r.output
        {
            println!("  [{}] {} tokens: {:?}", r.id, r.decode_steps, t);
        }
    }
    router.shutdown();
    Ok(())
}

fn cmd_characterize(argv: &[String]) -> Result<()> {
    let cmd = Command::new("characterize",
                           "Figure-4 style breakdown (device model)")
        .opt("device", "A100|H100", Some("A100"))
        .flag("sys-opt", "apply SDPA+compile+AutoQuant levers")
        .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let dev: &DeviceSpec = DeviceSpec::by_name(&a.get_or("device", "A100"))
        .ok_or_else(|| anyhow::anyhow!("unknown device"))?;
    let lv = if a.flag("sys-opt") {
        Levers::sys_opt()
    } else {
        Levers::baseline()
    };
    let rows = standard_breakdown_rows(dev, &lv);
    println!("{}", render(&rows));
    Ok(())
}

fn cmd_autoquant(argv: &[String]) -> Result<()> {
    let cmd = Command::new("autoquant", "calibrate quantization (§4.2)")
        .opt("model", "llama|chameleon", Some("llama"))
        .opt("iters", "timing iterations", Some("20"))
        .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let dir = mmserve::artifacts_dir().join(a.get_or("model", "llama"));
    let engine = Engine::load(&dir)?;
    let rep = autoquant::calibrate_decode(&engine, a.get_usize("iters", 20))?;
    for t in &rep.timings {
        println!("  {:<24} {:>9.3} ms", t.stage, t.mean_s * 1e3);
    }
    println!("chosen: {:?}", rep.chosen);
    Ok(())
}

fn cmd_stages(argv: &[String]) -> Result<()> {
    let cmd = Command::new("stages", "list AOT stages per model")
        .opt("model", "model dir name", Some("llama"))
        .flag("help", "show usage");
    let a = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    if a.flag("help") {
        println!("{}", cmd.usage());
        return Ok(());
    }
    let dir = mmserve::artifacts_dir().join(a.get_or("model", "llama"));
    let man = mmserve::runtime::manifest::Manifest::load(&dir)?;
    println!("model {} — {} stages", man.model, man.stages.len());
    for name in man.stage_names() {
        let s = man.stage(name)?;
        println!("  {:<28} {} weights, {} args, {} outputs",
                 name, s.weights.len(), s.args.len(), s.outputs.len());
    }
    Ok(())
}
