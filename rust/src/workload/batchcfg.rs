//! Table 3: maximum batch size per task that fits a single A100's 80 GB
//! HBM — solved from weights + per-sample KV/activation footprints.

use crate::models::TaskKind;
use crate::perfmodel::configs::{PaperDecoder, PaperHstu, PaperSeamless,
                                CHAMELEON_34B, HSTU_14L, LLAMA_34B,
                                SEAMLESS_M4T};
use crate::perfmodel::device::DeviceSpec;

use super::spec_for;

/// Per-sample device-memory footprint at max context for a task, bytes.
pub fn per_sample_bytes(task: TaskKind) -> f64 {
    let w = spec_for(task);
    // Static KV caches are sized for the worst case the task permits
    // (paper §4.1.2), so capacity is set by max lengths, not averages.
    let ctx = (w.input.max + w.output.max.min(10_000)) as f64;
    match task {
        TaskKind::TextToText => decoder_sample(&LLAMA_34B, ctx, 1),
        TaskKind::ImageToText | TaskKind::ImageTextToText => {
            decoder_sample(&CHAMELEON_34B, ctx, 1)
        }
        TaskKind::TextToImage => decoder_sample(&CHAMELEON_34B, ctx, 2),
        TaskKind::SpeechToSpeech
        | TaskKind::SpeechToText
        | TaskKind::TextToTextTrans
        | TaskKind::TextToSpeech => seamless_sample(&SEAMLESS_M4T, w.input.avg,
                                                    w.decode_steps),
        TaskKind::HistoryToAction => hstu_sample(&HSTU_14L, w.input.avg),
    }
}

fn decoder_sample(cfg: &PaperDecoder, ctx: f64, streams: usize) -> f64 {
    // KV at full context (×2 for contrastive) + activation slack
    let kv = streams as f64 * ctx * cfg.kv_bytes_per_token();
    let act = 8.0 * ctx * cfg.d_model as f64 * 2.0;
    kv + act
}

fn seamless_sample(cfg: &PaperSeamless, src: f64, steps: f64) -> f64 {
    let kv = cfg.beam as f64 * steps * cfg.kv_bytes_per_token();
    let enc = src * cfg.d_model as f64 * 2.0 * 4.0;
    kv + enc
}

fn hstu_sample(cfg: &PaperHstu, seq: f64) -> f64 {
    // activations across layers dominate (no KV): ~3 tensors resident
    // of [seq, 4*d] at fp16 plus attention workspace at capped length.
    let act = 3.0 * seq * (4 * cfg.d_model) as f64 * 2.0;
    let attn_ws = (cfg.n_heads as f64)
        * (cfg.capped_len as f64) * (cfg.capped_len as f64) * 2.0;
    act + attn_ws
}

/// Weights resident for a task's model, bytes.
pub fn weight_bytes(task: TaskKind) -> f64 {
    match task.model() {
        crate::models::ModelKind::Llama => LLAMA_34B.weight_bytes(),
        crate::models::ModelKind::Chameleon => CHAMELEON_34B.weight_bytes(),
        crate::models::ModelKind::Seamless => SEAMLESS_M4T.weight_bytes(),
        crate::models::ModelKind::Hstu => HSTU_14L.weight_bytes(),
    }
}

/// Largest batch that fits the device (Table 3's "Max. Batch Size"),
/// with a fraction of HBM reserved for the allocator/workspace.
pub fn max_batch(task: TaskKind, dev: &DeviceSpec) -> usize {
    let reserve = 0.10 * dev.hbm_capacity;
    let free = dev.hbm_capacity - reserve - weight_bytes(task);
    if free <= 0.0 {
        return 0;
    }
    (free / per_sample_bytes(task)).floor() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::device::A100;

    /// The paper's Table 3 values; the solve should land in the same
    /// order of magnitude and preserve the ordering llama < chameleon
    /// < hstu < seamless.
    #[test]
    fn table3_shape_holds() {
        let llama = max_batch(TaskKind::TextToText, &A100);
        let cham = max_batch(TaskKind::ImageToText, &A100);
        let seam = max_batch(TaskKind::SpeechToText, &A100);
        let hstu = max_batch(TaskKind::HistoryToAction, &A100);
        assert!(llama >= 1 && llama <= 32, "llama {llama}");
        assert!(cham > llama, "cham {cham} !> llama {llama}");
        assert!(seam > cham, "seam {seam} !> cham {cham}");
        assert!(hstu > 4, "hstu {hstu}");
    }

    #[test]
    fn all_tasks_fit_at_batch_one() {
        for t in TaskKind::all() {
            assert!(max_batch(t, &A100) >= 1, "{t}");
        }
    }
}
