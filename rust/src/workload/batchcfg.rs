//! Table 3: maximum batch size per task that fits a single A100's 80 GB
//! HBM — solved from weights + per-sample KV/activation footprints,
//! under dense worst-case allocation and under paged allocation (pages
//! sized to the lengths the workload actually reaches).

use crate::kvpool::pages_for;
use crate::models::TaskKind;
use crate::perfmodel::configs::{PaperDecoder, PaperHstu, PaperSeamless,
                                CHAMELEON_34B, HSTU_14L, LLAMA_34B,
                                SEAMLESS_M4T};
use crate::perfmodel::device::DeviceSpec;
use crate::perfmodel::latency::{task_cost, TaskSpec};
use crate::perfmodel::levers::Levers;

use super::spec_for;

/// Per-sample device-memory footprint at max context for a task, bytes.
pub fn per_sample_bytes(task: TaskKind) -> f64 {
    let w = spec_for(task);
    // Static KV caches are sized for the worst case the task permits
    // (paper §4.1.2), so capacity is set by max lengths, not averages.
    let ctx = (w.input.max + w.output.max.min(10_000)) as f64;
    match task {
        TaskKind::TextToText => decoder_sample(&LLAMA_34B, ctx, 1),
        TaskKind::ImageToText | TaskKind::ImageTextToText => {
            decoder_sample(&CHAMELEON_34B, ctx, 1)
        }
        TaskKind::TextToImage => decoder_sample(&CHAMELEON_34B, ctx, 2),
        TaskKind::SpeechToSpeech
        | TaskKind::SpeechToText
        | TaskKind::TextToTextTrans
        | TaskKind::TextToSpeech => seamless_sample(&SEAMLESS_M4T, w.input.avg,
                                                    w.decode_steps),
        TaskKind::HistoryToAction => hstu_sample(&HSTU_14L, w.input.avg),
    }
}

fn decoder_sample(cfg: &PaperDecoder, ctx: f64, streams: usize) -> f64 {
    // KV at full context (×2 for contrastive) + activation slack
    let kv = streams as f64 * ctx * cfg.kv_bytes_per_token();
    let act = 8.0 * ctx * cfg.d_model as f64 * 2.0;
    kv + act
}

fn seamless_sample(cfg: &PaperSeamless, src: f64, steps: f64) -> f64 {
    let kv = cfg.beam as f64 * steps * cfg.kv_bytes_per_token();
    let enc = src * cfg.d_model as f64 * 2.0 * 4.0;
    kv + enc
}

fn hstu_sample(cfg: &PaperHstu, seq: f64) -> f64 {
    // activations across layers dominate (no KV): ~3 tensors resident
    // of [seq, 4*d] at fp16 plus attention workspace at capped length.
    let act = 3.0 * seq * (4 * cfg.d_model) as f64 * 2.0;
    let attn_ws = (cfg.n_heads as f64)
        * (cfg.capped_len as f64) * (cfg.capped_len as f64) * 2.0;
    act + attn_ws
}

/// Weights resident for a task's model, bytes.
pub fn weight_bytes(task: TaskKind) -> f64 {
    match task.model() {
        crate::models::ModelKind::Llama => LLAMA_34B.weight_bytes(),
        crate::models::ModelKind::Chameleon => CHAMELEON_34B.weight_bytes(),
        crate::models::ModelKind::Seamless => SEAMLESS_M4T.weight_bytes(),
        crate::models::ModelKind::Hstu => HSTU_14L.weight_bytes(),
    }
}

/// Largest batch that fits the device (Table 3's "Max. Batch Size"),
/// with a fraction of HBM reserved for the allocator/workspace.
pub fn max_batch(task: TaskKind, dev: &DeviceSpec) -> usize {
    let reserve = 0.10 * dev.hbm_capacity;
    let free = dev.hbm_capacity - reserve - weight_bytes(task);
    if free <= 0.0 {
        return 0;
    }
    (free / per_sample_bytes(task)).floor() as usize
}

/// Per-sample footprint under *paged* KV allocation: pages cover the
/// context a sample actually reaches (Table-2 average input + decode
/// steps, rounded up to page granularity) instead of the task's
/// worst-case `max` — the dense reservation the kvpool subsystem
/// eliminates. Non-KV activation terms are unchanged.
pub fn per_sample_bytes_paged(task: TaskKind, page_size: usize) -> f64 {
    let w = spec_for(task);
    let page = |tokens: f64| -> f64 {
        (pages_for(tokens.ceil() as usize, page_size) * page_size) as f64
    };
    // Page-granularity rounding can only waste up to one page; a paged
    // sample never costs more than the dense worst-case reservation.
    let paged = match task {
        TaskKind::TextToText => {
            let ctx = page(w.input.avg + w.decode_steps);
            ctx * LLAMA_34B.kv_bytes_per_token()
                + 8.0 * ctx * LLAMA_34B.d_model as f64 * 2.0
        }
        TaskKind::ImageToText | TaskKind::ImageTextToText => {
            let ctx = page(w.input.avg + w.decode_steps);
            ctx * CHAMELEON_34B.kv_bytes_per_token()
                + 8.0 * ctx * CHAMELEON_34B.d_model as f64 * 2.0
        }
        TaskKind::TextToImage => {
            let ctx = page(w.input.avg + w.decode_steps);
            2.0 * ctx * CHAMELEON_34B.kv_bytes_per_token()
                + 8.0 * ctx * CHAMELEON_34B.d_model as f64 * 2.0
        }
        // Seamless beams and HSTU activations are not KV-slot bound;
        // paging gives them nothing beyond the dense solve.
        _ => per_sample_bytes(task),
    };
    paged.min(per_sample_bytes(task))
}

/// Table 3 under paged allocation (same reserve policy as
/// [`max_batch`]).
pub fn max_batch_paged(task: TaskKind, dev: &DeviceSpec,
                       page_size: usize) -> usize {
    let reserve = 0.10 * dev.hbm_capacity;
    let free = dev.hbm_capacity - reserve - weight_bytes(task);
    if free <= 0.0 {
        return 0;
    }
    (free / per_sample_bytes_paged(task, page_size)).floor() as usize
}

/// One Table-3 comparison row: achievable batch dense vs. paged.
#[derive(Debug, Clone)]
pub struct PagedBatchRow {
    pub task: TaskKind,
    pub dense: usize,
    pub paged: usize,
}

/// The paged-vs-dense Table-3 rows for the decoder tasks (the ones KV
/// capacity bounds), in `TaskKind::all()` order.
pub fn paged_vs_dense_rows(dev: &DeviceSpec, page_size: usize)
                           -> Vec<PagedBatchRow> {
    [
        TaskKind::TextToText,
        TaskKind::ImageToText,
        TaskKind::ImageTextToText,
        TaskKind::TextToImage,
    ]
    .into_iter()
    .map(|task| PagedBatchRow {
        task,
        dense: max_batch(task, dev),
        paged: max_batch_paged(task, dev, page_size),
    })
    .collect()
}

// ==========================================================================
// Chunked-prefill interference projection (paper scale)
// ==========================================================================

/// One task's projected prefill/decode-interference numbers, whole vs.
/// chunked prefill (ideal chunk-append kernel: each chunk costs the
/// *marginal* prefill work for its token range).
#[derive(Debug, Clone)]
pub struct ChunkedPrefillRow {
    pub task: TaskKind,
    /// Table-2 average input length used as the prompt.
    pub prompt_len: usize,
    pub chunks: usize,
    /// TTFT = one whole-prompt prefill monopolizing a tick.
    pub ttft_whole_ms: f64,
    /// TTFT with one interleaved decode tick per extra chunk — the
    /// "one decode tick per chunk" regression bound.
    pub ttft_chunked_ms: f64,
    /// Worst decode-tick stall behind one admission (whole prompt).
    pub stall_whole_ms: f64,
    /// Worst decode-tick stall with the chunk budget (max marginal
    /// chunk cost).
    pub stall_chunked_ms: f64,
    /// One batched decode step at full context (the tick floor).
    pub decode_tick_ms: f64,
}

fn decoder_cfg(task: TaskKind) -> Option<&'static PaperDecoder> {
    match task {
        TaskKind::TextToText => Some(&LLAMA_34B),
        TaskKind::ImageToText
        | TaskKind::ImageTextToText
        | TaskKind::TextToImage => Some(&CHAMELEON_34B),
        _ => None,
    }
}

fn prefill_ms(cfg: &'static PaperDecoder, n: usize, dev: &DeviceSpec)
              -> f64 {
    if n == 0 {
        return 0.0;
    }
    let spec = TaskSpec::Decoder {
        cfg,
        batch: 1,
        prompt_len: n,
        decode_steps: 1,
        decodes_per_step: 1,
    };
    task_cost(&spec, dev, &Levers::baseline()).prefill_wall * 1e3
}

/// Project the prefill/decode interference of one decoder task under
/// whole-prompt vs. chunked admission (`None` for non-decoder tasks).
///
/// The model: a decoding request's tick is stalled by however much
/// prefill work the scheduler admits into that tick. Whole-prompt
/// admission stalls one tick by the full prompt's prefill; a chunk
/// budget bounds the stall by the most expensive single chunk (the
/// marginal cost `P(i·C) − P((i−1)·C)`, superlinear in context via
/// attention), at the price of one extra decode tick of TTFT per
/// chunk.
pub fn chunked_prefill_projection(task: TaskKind, dev: &DeviceSpec,
                                  chunk: usize)
                                  -> Option<ChunkedPrefillRow> {
    let cfg = decoder_cfg(task)?;
    let w = spec_for(task);
    let prompt = (w.input.avg.round() as usize).max(1);
    let chunk = chunk.max(1);
    let chunks = (prompt + chunk - 1) / chunk;
    let whole = prefill_ms(cfg, prompt, dev);
    let decode_tick_ms = {
        let spec = TaskSpec::Decoder {
            cfg,
            batch: 1,
            prompt_len: prompt,
            decode_steps: 1,
            decodes_per_step: 1,
        };
        task_cost(&spec, dev, &Levers::baseline()).decode_wall * 1e3
    };
    let mut stall_chunked = 0.0f64;
    let mut prev = 0.0f64;
    for i in 1..=chunks {
        let end = (i * chunk).min(prompt);
        let p = prefill_ms(cfg, end, dev);
        stall_chunked = stall_chunked.max(p - prev);
        prev = p;
    }
    Some(ChunkedPrefillRow {
        task,
        prompt_len: prompt,
        chunks,
        ttft_whole_ms: whole,
        ttft_chunked_ms: whole + (chunks as f64 - 1.0) * decode_tick_ms,
        stall_whole_ms: whole,
        stall_chunked_ms: stall_chunked,
        decode_tick_ms,
    })
}

/// The chunked-prefill projection for the KV-bound decoder tasks.
pub fn chunked_prefill_rows(dev: &DeviceSpec, chunk: usize)
                            -> Vec<ChunkedPrefillRow> {
    [
        TaskKind::TextToText,
        TaskKind::ImageToText,
        TaskKind::ImageTextToText,
        TaskKind::TextToImage,
    ]
    .into_iter()
    .filter_map(|task| chunked_prefill_projection(task, dev, chunk))
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::device::A100;

    /// The paper's Table 3 values; the solve should land in the same
    /// order of magnitude and preserve the ordering llama < chameleon
    /// < hstu < seamless.
    #[test]
    fn table3_shape_holds() {
        let llama = max_batch(TaskKind::TextToText, &A100);
        let cham = max_batch(TaskKind::ImageToText, &A100);
        let seam = max_batch(TaskKind::SpeechToText, &A100);
        let hstu = max_batch(TaskKind::HistoryToAction, &A100);
        assert!(llama >= 1 && llama <= 32, "llama {llama}");
        assert!(cham > llama, "cham {cham} !> llama {llama}");
        assert!(seam > cham, "seam {seam} !> cham {cham}");
        assert!(hstu > 4, "hstu {hstu}");
    }

    #[test]
    fn all_tasks_fit_at_batch_one() {
        for t in TaskKind::all() {
            assert!(max_batch(t, &A100) >= 1, "{t}");
        }
    }

    /// Paged allocation sizes KV for reached context, not worst case —
    /// every decoder task's achievable batch must grow, and by the
    /// most for the long-max/short-avg tasks (T-T's 10k output cap).
    #[test]
    fn paged_batch_dominates_dense() {
        for row in paged_vs_dense_rows(&A100, 16) {
            assert!(
                row.paged >= row.dense,
                "{:?}: paged {} < dense {}",
                row.task, row.paged, row.dense
            );
        }
        let tt = max_batch(TaskKind::TextToText, &A100);
        let tt_paged = max_batch_paged(TaskKind::TextToText, &A100, 16);
        assert!(
            tt_paged >= 4 * tt.max(1),
            "T-T paged {tt_paged} should be ≫ dense {tt}"
        );
    }

    /// Tentpole projection: a chunk budget bounds the worst decode-tick
    /// stall strictly below the whole-prompt prefill, and TTFT regresses
    /// by exactly one decode tick per extra chunk (the acceptance
    /// criterion's "one-tick bound").
    #[test]
    fn chunked_prefill_bounds_stall_and_ttft() {
        // I-T's 1030-token prompt at a 256-token chunk: 5 chunks.
        let r = chunked_prefill_projection(TaskKind::ImageToText, &A100,
                                           256)
            .unwrap();
        assert_eq!(r.chunks, 5);
        assert!(r.stall_chunked_ms > 0.0);
        assert!(
            r.stall_chunked_ms < r.stall_whole_ms,
            "chunked stall {} !< whole {}",
            r.stall_chunked_ms, r.stall_whole_ms
        );
        let extra = r.ttft_chunked_ms - r.ttft_whole_ms;
        let want = 4.0 * r.decode_tick_ms;
        assert!(
            (extra - want).abs() < 1e-6 * (1.0 + r.ttft_whole_ms),
            "TTFT regression {extra} vs one-tick bound {want}"
        );
        // Non-decoder tasks have no projection.
        assert!(chunked_prefill_projection(TaskKind::SpeechToText, &A100,
                                           256)
            .is_none());
        // A chunk larger than the prompt degenerates to whole-prompt.
        let one = chunked_prefill_projection(TaskKind::TextToText, &A100,
                                             4096)
            .unwrap();
        assert_eq!(one.chunks, 1);
        assert_eq!(one.stall_chunked_ms, one.stall_whole_ms);
        assert_eq!(one.ttft_chunked_ms, one.ttft_whole_ms);
    }

    #[test]
    fn chunked_prefill_rows_cover_decoder_tasks() {
        let rows = chunked_prefill_rows(&A100, 256);
        assert_eq!(rows.len(), 4);
        for r in rows {
            assert!(r.stall_chunked_ms <= r.stall_whole_ms + 1e-12);
            assert!(r.ttft_chunked_ms >= r.ttft_whole_ms);
            assert!(r.decode_tick_ms > 0.0);
        }
    }

    #[test]
    fn paged_footprint_rounds_to_page_multiples() {
        let a = per_sample_bytes_paged(TaskKind::ImageToText, 16);
        let b = per_sample_bytes_paged(TaskKind::ImageToText, 1);
        // Coarser pages can only round up.
        assert!(a >= b);
        // Non-KV-bound tasks are unchanged by paging.
        let h = per_sample_bytes_paged(TaskKind::HistoryToAction, 16);
        assert_eq!(h, per_sample_bytes(TaskKind::HistoryToAction));
    }
}
