//! Open-loop arrival engine: timestamped request streams shaped like
//! fleet-scale traffic.
//!
//! The kvpool/routing replays historically enqueued the whole mix at
//! t = 0 ("closed loop"), so admission policy was the only thing ever
//! stressed — queueing delay, rate transients, and scaling policy were
//! invisible. This module generates the *arrival process* instead: a
//! seeded, deterministic sequence of `(time, SimRequest)` pairs drawn
//! from
//!
//! * a **rate curve** — homogeneous Poisson (`poisson:R`) or a smooth
//!   diurnal curve (`diurnal:base:peak:period`, sampled by
//!   Lewis–Shedler thinning against the peak rate);
//! * **burst episodes** (`burst:at:len:mult`) — flash crowds that
//!   multiply the instantaneous rate inside a window, realized as
//!   extra arrivals placed strictly inside `[at, at+len)`;
//! * a **Zipf tenant population** (`zipf:s`) — multi-tenant workloads
//!   draw their shared system prompt by rank-frequency popularity, so
//!   a handful of tenants dominate the stream the way shared prompts
//!   do at fleet scale;
//! * **conversation follow-ups** (`followups:p`, `think:t`) — a slice
//!   of requests re-arrive after their estimated service plus an
//!   exponential think time, carrying the full prior turn (prompt +
//!   the decoded tokens the sim will deterministically emit) as a
//!   *warm prefix*, plus a fresh user tail. Follow-ups are where
//!   prefix caching pays under open-loop load.
//!
//! Everything is a pure function of `(ReplayConfig, ArrivalSpec)`:
//! same seed, same stream, bit for bit — the property-test harness in
//! `rust/tests/property_workload.rs` checks the statistics (Poisson
//! mean/CV, Zipf slope, burst containment) *and* the bit-identity.

use crate::kvpool::replay::{generate_workload, ReplayConfig,
                            SimFamily, SimRequest, SIM_DECODE_COST,
                            SIM_PREFILL_TOKEN_COST};
use crate::substrate::rng::Rng;

/// Follow-up request ids live far above the base/burst id space
/// (base ids are 1..=requests, burst ids continue from there) and far
/// below the replay's ghost-fork space (1 << 48), so a follow-up can
/// never collide with its parent or with beam ghosts.
pub const FOLLOWUP_ID_BASE: u64 = 1 << 32;

/// Seed salt for the arrival clock's RNG stream: timestamps draw from
/// a stream independent of `generate_workload`'s, so the *payloads*
/// of the base mix stay byte-identical to the closed-loop workload at
/// the same seed.
const ARRIVAL_SALT: u64 = 0xA211_1A75_0C10_CC01;

/// Time-varying arrival rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RateCurve {
    /// Homogeneous Poisson at `rate` requests per simulated time unit.
    Poisson { rate: f64 },
    /// Smooth day-shaped curve: `base` at t = 0, cresting at `peak`
    /// mid-`period`, back to `base` — one cosine hump per period.
    Diurnal { base: f64, peak: f64, period: f64 },
}

impl RateCurve {
    /// Instantaneous rate at simulated time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            RateCurve::Poisson { rate } => rate,
            RateCurve::Diurnal { base, peak, period } => {
                let p = period.max(1e-9);
                let phase = (t / p) * std::f64::consts::TAU;
                base + (peak - base) * 0.5 * (1.0 - phase.cos())
            }
        }
    }

    /// The thinning envelope: the curve's maximum instantaneous rate.
    pub fn max_rate(&self) -> f64 {
        match *self {
            RateCurve::Poisson { rate } => rate,
            RateCurve::Diurnal { base, peak, .. } => base.max(peak),
        }
    }
}

/// One flash-crowd episode: inside `[at, at + len)` the arrival rate
/// is multiplied by `mult` (realized as extra injected arrivals on
/// top of the base process).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BurstSpec {
    pub at: f64,
    pub len: f64,
    pub mult: f64,
}

impl BurstSpec {
    /// Does the window contain `t`? (Half-open: `at <= t < at+len`.)
    pub fn contains(&self, t: f64) -> bool {
        t >= self.at && t < self.at + self.len
    }
}

/// Which regime of the rate curve an arrival landed in — the replay
/// reports TTFT percentiles per phase, so a burst's queueing damage
/// is visible separately from steady-state latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ArrivalPhase {
    /// Off-peak steady state (a Poisson curve is all Base).
    Base,
    /// The diurnal crest: instantaneous rate ≥ the base/peak midpoint.
    Peak,
    /// Inside a configured burst window (wins over Base/Peak).
    Burst,
}

impl ArrivalPhase {
    pub fn label(&self) -> &'static str {
        match self {
            ArrivalPhase::Base => "base",
            ArrivalPhase::Peak => "peak",
            ArrivalPhase::Burst => "burst",
        }
    }

    /// All phases, in report order.
    pub const ALL: [ArrivalPhase; 3] = [
        ArrivalPhase::Base,
        ArrivalPhase::Peak,
        ArrivalPhase::Burst,
    ];
}

/// The open-loop arrival process: rate curve + burst episodes +
/// conversation and tenant shaping. Parsed from the CLI's
/// `--arrivals` spec; `None` in [`ReplayConfig::arrivals`] keeps the
/// historical closed-loop replay (and its RNG stream) bit-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalSpec {
    pub curve: RateCurve,
    /// Flash-crowd episodes layered on the curve.
    pub bursts: Vec<BurstSpec>,
    /// Percent of arrivals that spawn a warm-prefix follow-up turn.
    pub followup_percent: usize,
    /// Mean exponential think time before a follow-up re-arrives
    /// (measured from the parent's estimated completion).
    pub think_mean: f64,
    /// Zipf exponent for tenant popularity (multi-tenant workloads);
    /// 0 keeps the uniform tenant draw.
    pub zipf_s: f64,
}

impl ArrivalSpec {
    /// Defaults for the knobs a spec string doesn't name.
    fn with_curve(curve: RateCurve) -> ArrivalSpec {
        ArrivalSpec {
            curve,
            bursts: Vec::new(),
            followup_percent: 20,
            think_mean: 25.0,
            zipf_s: 1.1,
        }
    }

    /// Parse an `--arrivals` spec: `+`-separated segments, exactly one
    /// of which is a rate curve.
    ///
    /// * `poisson:R` — homogeneous Poisson at rate `R`;
    /// * `diurnal:BASE:PEAK:PERIOD` — cosine day curve;
    /// * `burst:AT:LEN:MULT` — flash crowd (repeatable);
    /// * `followups:P` — percent of arrivals with a follow-up turn;
    /// * `think:T` — mean think time before a follow-up;
    /// * `zipf:S` — tenant-popularity exponent (0 = uniform).
    ///
    /// Example: `diurnal:0.25:0.9:180+burst:60:30:4+followups:25`.
    pub fn parse(spec: &str) -> Result<ArrivalSpec, String> {
        let mut curve: Option<RateCurve> = None;
        let mut bursts: Vec<BurstSpec> = Vec::new();
        let mut followups: Option<usize> = None;
        let mut think: Option<f64> = None;
        let mut zipf: Option<f64> = None;
        let num = |part: &str, field: &str| -> Result<f64, String> {
            field.trim().parse::<f64>().map_err(|_| {
                format!("arrivals segment {part:?}: bad number \
                         {field:?}")
            })
        };
        for part in spec.split('+') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let mut it = part.split(':');
            let kind = it.next().unwrap_or("").trim();
            let args: Vec<&str> = it.collect();
            match kind {
                "poisson" => {
                    if args.len() != 1 {
                        return Err(format!(
                            "arrivals segment {part:?}: want poisson:R"
                        ));
                    }
                    let rate = num(part, args[0])?;
                    if !(rate > 0.0) {
                        return Err(format!(
                            "arrivals segment {part:?}: rate must be \
                             > 0"
                        ));
                    }
                    if curve.replace(RateCurve::Poisson { rate })
                        .is_some()
                    {
                        return Err("arrivals: more than one rate \
                                    curve".into());
                    }
                }
                "diurnal" => {
                    if args.len() != 3 {
                        return Err(format!(
                            "arrivals segment {part:?}: want \
                             diurnal:BASE:PEAK:PERIOD"
                        ));
                    }
                    let base = num(part, args[0])?;
                    let peak = num(part, args[1])?;
                    let period = num(part, args[2])?;
                    if !(base >= 0.0 && peak > 0.0 && period > 0.0) {
                        return Err(format!(
                            "arrivals segment {part:?}: want base ≥ 0, \
                             peak > 0, period > 0"
                        ));
                    }
                    let c = RateCurve::Diurnal { base, peak, period };
                    if curve.replace(c).is_some() {
                        return Err("arrivals: more than one rate \
                                    curve".into());
                    }
                }
                "burst" => {
                    if args.len() != 3 {
                        return Err(format!(
                            "arrivals segment {part:?}: want \
                             burst:AT:LEN:MULT"
                        ));
                    }
                    let at = num(part, args[0])?;
                    let len = num(part, args[1])?;
                    let mult = num(part, args[2])?;
                    if !(at >= 0.0 && len > 0.0 && mult >= 1.0) {
                        return Err(format!(
                            "arrivals segment {part:?}: want at ≥ 0, \
                             len > 0, mult ≥ 1"
                        ));
                    }
                    bursts.push(BurstSpec { at, len, mult });
                }
                "followups" => {
                    if args.len() != 1 {
                        return Err(format!(
                            "arrivals segment {part:?}: want \
                             followups:P"
                        ));
                    }
                    let p = num(part, args[0])?;
                    if !(0.0..=100.0).contains(&p) {
                        return Err(format!(
                            "arrivals segment {part:?}: percent out \
                             of range"
                        ));
                    }
                    followups = Some(p as usize);
                }
                "think" => {
                    if args.len() != 1 {
                        return Err(format!(
                            "arrivals segment {part:?}: want think:T"
                        ));
                    }
                    let t = num(part, args[0])?;
                    if !(t >= 0.0) {
                        return Err(format!(
                            "arrivals segment {part:?}: think must be \
                             ≥ 0"
                        ));
                    }
                    think = Some(t);
                }
                "zipf" => {
                    if args.len() != 1 {
                        return Err(format!(
                            "arrivals segment {part:?}: want zipf:S"
                        ));
                    }
                    let s = num(part, args[0])?;
                    if !(s >= 0.0) {
                        return Err(format!(
                            "arrivals segment {part:?}: exponent must \
                             be ≥ 0"
                        ));
                    }
                    zipf = Some(s);
                }
                other => {
                    return Err(format!(
                        "unknown arrivals segment {other:?} (want \
                         poisson|diurnal|burst|followups|think|zipf)"
                    ));
                }
            }
        }
        let Some(curve) = curve else {
            return Err("arrivals: no rate curve (need poisson:R or \
                        diurnal:BASE:PEAK:PERIOD)".into());
        };
        let mut out = ArrivalSpec::with_curve(curve);
        out.bursts = bursts;
        if let Some(p) = followups {
            out.followup_percent = p;
        }
        if let Some(t) = think {
            out.think_mean = t;
        }
        if let Some(s) = zipf {
            out.zipf_s = s;
        }
        Ok(out)
    }

    /// Which phase an arrival at time `t` belongs to. Burst windows
    /// win; a diurnal curve splits the rest at the base/peak midpoint;
    /// a Poisson curve is all Base.
    pub fn phase_at(&self, t: f64) -> ArrivalPhase {
        if self.bursts.iter().any(|b| b.contains(t)) {
            return ArrivalPhase::Burst;
        }
        match self.curve {
            RateCurve::Poisson { .. } => ArrivalPhase::Base,
            RateCurve::Diurnal { base, peak, .. } => {
                if self.curve.rate_at(t) >= 0.5 * (base + peak) {
                    ArrivalPhase::Peak
                } else {
                    ArrivalPhase::Base
                }
            }
        }
    }
}

impl std::fmt::Display for ArrivalSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.curve {
            RateCurve::Poisson { rate } => {
                write!(f, "poisson:{rate}")?;
            }
            RateCurve::Diurnal { base, peak, period } => {
                write!(f, "diurnal:{base}:{peak}:{period}")?;
            }
        }
        for b in &self.bursts {
            write!(f, "+burst:{}:{}:{}", b.at, b.len, b.mult)?;
        }
        write!(f, "+followups:{}+think:{}+zipf:{}",
               self.followup_percent, self.think_mean, self.zipf_s)
    }
}

/// One timestamped arrival of the open-loop stream.
#[derive(Debug, Clone)]
pub struct TimedArrival {
    /// Absolute simulated arrival time.
    pub at: f64,
    /// Rate-curve phase at `at` (per-phase TTFT reporting).
    pub phase: ArrivalPhase,
    /// Id of the conversation turn this follows up on (`None` for
    /// first turns and burst injections).
    pub followup_of: Option<u64>,
    pub req: SimRequest,
}

/// Inverse-CDF table for a Zipf(s) distribution over `n` ranks:
/// `cdf[k]` is P(rank ≤ k). Rank 0 is the most popular tenant.
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let n = n.max(1);
    let mut w: Vec<f64> =
        (1..=n).map(|k| (k as f64).powf(-s)).collect();
    let sum: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / sum;
        *x = acc;
    }
    // Guard float drift: the last bucket must cover u → 1.
    if let Some(last) = w.last_mut() {
        *last = 1.0;
    }
    w
}

/// Draw a rank from a [`zipf_cdf`] table with a uniform `u` in [0,1).
pub fn zipf_pick(cdf: &[f64], u: f64) -> usize {
    cdf.iter()
        .position(|&c| u < c)
        .unwrap_or(cdf.len().saturating_sub(1))
}

/// One exponential gap at `rate` (mean `1/rate`).
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    // f64() ∈ [0,1) ⇒ 1-u ∈ (0,1] ⇒ ln ≤ 0 ⇒ gap ≥ 0, never NaN.
    -(1.0 - rng.f64()).ln() / rate.max(1e-9)
}

/// Estimated solo service time of one request on the simulated
/// hardware (prefill tokens priced per token + one decode dispatch
/// per output token) — the follow-up scheduler's "the user read the
/// answer" offset.
fn service_estimate(req: &SimRequest) -> f64 {
    req.tokens.len() as f64 * SIM_PREFILL_TOKEN_COST
        + req.decode as f64 * SIM_DECODE_COST
}

/// Synthesize one extra request (burst injections) with the same
/// per-request shape as [`generate_workload`], drawn from the arrival
/// RNG stream.
fn synth_request(id: u64, cfg: &ReplayConfig, zipf: Option<&[f64]>,
                 rng: &mut Rng) -> SimRequest {
    let tenants = cfg.tenants.max(1);
    let long = rng.usize(0, 100) < cfg.long_percent;
    let (pr, dr) = if long {
        (cfg.long_prompt, cfg.long_decode)
    } else {
        (cfg.short_prompt, cfg.short_decode)
    };
    let extra = rng.usize(pr.0, pr.1 + 1);
    let decode = rng.usize(dr.0, dr.1 + 1).max(1);
    let tenant = if tenants > 1 {
        match zipf {
            Some(cdf) => zipf_pick(cdf, rng.f64()),
            None => rng.usize(0, tenants),
        }
    } else {
        0
    };
    let family = match cfg.mix {
        Some(m) => {
            let roll = rng.usize(0, 100);
            if roll < m.seamless_percent {
                SimFamily::Seamless
            } else if roll < m.seamless_percent + m.hstu_percent {
                SimFamily::Hstu
            } else {
                SimFamily::Chat
            }
        }
        None => SimFamily::Chat,
    };
    let decode = if family == SimFamily::Hstu { 0 } else { decode };
    let mut tokens: Vec<i32> = (0..cfg.system_prompt_len)
        .map(|i| ((i + tenant * 101) % 200) as i32)
        .collect();
    tokens.extend((0..extra).map(|_| rng.range(300, 800) as i32));
    SimRequest { id, tokens, decode, tenant, family }
}

/// Build the follow-up turn of a conversation: the parent's full
/// prompt, the exact token stream the sim will deterministically
/// decode for it (the replay emits `900 + pos % 50` at position
/// `pos`), and a fresh short user tail — so the follow-up's leading
/// blocks are a *warm prefix* wherever the parent's KV chain is still
/// cached.
fn followup_request(parent: &SimRequest, cfg: &ReplayConfig,
                    rng: &mut Rng) -> SimRequest {
    let mut tokens = parent.tokens.clone();
    let p0 = tokens.len();
    for k in 0..parent.decode {
        tokens.push(900 + ((p0 + k) % 50) as i32);
    }
    let extra =
        rng.usize(cfg.short_prompt.0, cfg.short_prompt.1 + 1);
    tokens.extend((0..extra).map(|_| rng.range(300, 800) as i32));
    let decode = if parent.family == SimFamily::Hstu {
        0
    } else {
        rng.usize(cfg.short_decode.0, cfg.short_decode.1 + 1).max(1)
    };
    // Never synthesize a turn the pool structurally cannot serve:
    // prompt + decode + 1 must fit max_seq.
    let cap = cfg.max_seq.saturating_sub(decode + 1).max(1);
    tokens.truncate(cap);
    SimRequest {
        id: parent.id + FOLLOWUP_ID_BASE,
        tokens,
        decode,
        tenant: parent.tenant,
        family: parent.family,
    }
}

fn sort_arrivals(v: &mut [TimedArrival]) {
    v.sort_by(|a, b| {
        a.at.total_cmp(&b.at).then(a.req.id.cmp(&b.req.id))
    });
}

/// The full timestamped stream for `cfg`: the base mix (byte-identical
/// payloads to [`generate_workload`]) spaced by the rate curve, burst
/// injections strictly inside their windows, and warm-prefix
/// follow-ups. Deterministic: a pure function of the config.
///
/// With `cfg.arrivals == None` every request arrives at t = 0 — the
/// closed-loop stream, so open-loop drivers degrade gracefully.
pub fn generate_arrivals(cfg: &ReplayConfig) -> Vec<TimedArrival> {
    let base = generate_workload(cfg);
    let Some(spec) = cfg.arrivals.clone() else {
        return base
            .into_iter()
            .map(|req| TimedArrival {
                at: 0.0,
                phase: ArrivalPhase::Base,
                followup_of: None,
                req,
            })
            .collect();
    };
    let mut rng = Rng::new(cfg.seed ^ ARRIVAL_SALT);
    // ---- base process: Lewis–Shedler thinning against the peak ----
    let rmax = spec.curve.max_rate().max(1e-9);
    let mut t = 0.0f64;
    let mut out: Vec<TimedArrival> = Vec::new();
    for req in base {
        loop {
            t += exp_gap(&mut rng, rmax);
            if rng.f64() * rmax < spec.curve.rate_at(t) {
                break;
            }
        }
        out.push(TimedArrival {
            at: t,
            phase: spec.phase_at(t),
            followup_of: None,
            req,
        });
    }
    // ---- burst injections: extra arrivals strictly inside windows --
    let tenants = cfg.tenants.max(1);
    let zipf = if tenants > 1 && spec.zipf_s > 0.0 {
        Some(zipf_cdf(tenants, spec.zipf_s))
    } else {
        None
    };
    let mut next_id = cfg.requests as u64 + 1;
    for b in &spec.bursts {
        let mid_rate = spec.curve.rate_at(b.at + 0.5 * b.len);
        let extra =
            (mid_rate * (b.mult - 1.0).max(0.0) * b.len).round()
                as usize;
        for _ in 0..extra {
            // f64() < 1 keeps the injection strictly inside the
            // half-open window.
            let at = b.at + rng.f64() * b.len;
            let req =
                synth_request(next_id, cfg, zipf.as_deref(), &mut rng);
            next_id += 1;
            out.push(TimedArrival {
                at,
                phase: spec.phase_at(at),
                followup_of: None,
                req,
            });
        }
    }
    sort_arrivals(&mut out);
    // ---- conversation follow-ups (warm-prefix re-arrivals) ---------
    if spec.followup_percent > 0 {
        let mut follows: Vec<TimedArrival> = Vec::new();
        for a in &out {
            if rng.usize(0, 100) >= spec.followup_percent {
                continue;
            }
            let think =
                exp_gap(&mut rng, 1.0 / spec.think_mean.max(1e-9));
            let at = a.at + service_estimate(&a.req) + think;
            let req = followup_request(&a.req, cfg, &mut rng);
            follows.push(TimedArrival {
                at,
                phase: spec.phase_at(at),
                followup_of: Some(a.req.id),
                req,
            });
        }
        out.extend(follows);
        sort_arrivals(&mut out);
    }
    out
}

/// Per-phase arrival counts (report order: base, peak, burst).
pub fn phase_counts(arrivals: &[TimedArrival])
                    -> Vec<(ArrivalPhase, usize)> {
    ArrivalPhase::ALL
        .iter()
        .map(|&p| {
            (p, arrivals.iter().filter(|a| a.phase == p).count())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn open_cfg(spec: &str) -> ReplayConfig {
        ReplayConfig {
            requests: 48,
            tenants: 4,
            arrivals: Some(ArrivalSpec::parse(spec).unwrap()),
            ..ReplayConfig::default()
        }
    }

    #[test]
    fn parse_accepts_full_spec_and_rejects_garbage() {
        let s = ArrivalSpec::parse(
            "diurnal:0.25:0.9:180+burst:60:30:4+burst:200:20:6\
             +followups:30+think:10+zipf:1.3",
        )
        .unwrap();
        assert_eq!(s.bursts.len(), 2);
        assert_eq!(s.followup_percent, 30);
        assert_eq!(s.think_mean, 10.0);
        assert_eq!(s.zipf_s, 1.3);
        assert!(matches!(s.curve, RateCurve::Diurnal { .. }));
        let p = ArrivalSpec::parse("poisson:2.5").unwrap();
        assert!(matches!(p.curve,
                         RateCurve::Poisson { rate } if rate == 2.5));
        assert!(p.bursts.is_empty());
        for bad in [
            "",
            "burst:1:2:3",            // no curve
            "poisson:0",              // zero rate
            "poisson:2+diurnal:1:2:3", // two curves
            "diurnal:1:2",            // missing arg
            "burst:5:0:2",            // zero-length window
            "burst:5:10:0.5",         // de-amplifying "burst"
            "warp:9",                 // unknown segment
            "poisson:wat",            // not a number
            "followups:140",          // percent out of range
        ] {
            assert!(ArrivalSpec::parse(bad).is_err(), "{bad:?}");
        }
        // Round-trip: Display output re-parses to the same spec.
        let again = ArrivalSpec::parse(&s.to_string()).unwrap();
        assert_eq!(again, s);
    }

    #[test]
    fn diurnal_curve_crests_mid_period_and_phases_split() {
        let s =
            ArrivalSpec::parse("diurnal:0.2:1.0:100+burst:10:5:3")
                .unwrap();
        assert!((s.curve.rate_at(0.0) - 0.2).abs() < 1e-9);
        assert!((s.curve.rate_at(50.0) - 1.0).abs() < 1e-9);
        assert!((s.curve.rate_at(100.0) - 0.2).abs() < 1e-9);
        assert_eq!(s.curve.max_rate(), 1.0);
        assert_eq!(s.phase_at(50.0), ArrivalPhase::Peak);
        assert_eq!(s.phase_at(99.0), ArrivalPhase::Base);
        // Burst wins over the underlying curve phase.
        assert_eq!(s.phase_at(12.0), ArrivalPhase::Burst);
        assert_eq!(s.phase_at(15.0), ArrivalPhase::Base,
                   "window is half-open");
    }

    #[test]
    fn zipf_cdf_is_monotone_and_rank0_dominates() {
        let cdf = zipf_cdf(6, 1.2);
        assert_eq!(cdf.len(), 6);
        assert!(cdf.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*cdf.last().unwrap(), 1.0);
        // Rank 0 holds the largest single mass.
        let m0 = cdf[0];
        assert!(m0 > 1.0 / 6.0, "rank-0 mass {m0}");
        assert_eq!(zipf_pick(&cdf, 0.0), 0);
        assert_eq!(zipf_pick(&cdf, 0.999_999), 5);
    }

    #[test]
    fn closed_loop_config_degenerates_to_t_zero() {
        let cfg = ReplayConfig::default();
        let arr = generate_arrivals(&cfg);
        assert_eq!(arr.len(), cfg.requests);
        assert!(arr.iter().all(|a| a.at == 0.0));
        assert!(arr.iter().all(|a| a.phase == ArrivalPhase::Base));
        // Payloads are exactly the closed-loop workload.
        let base = generate_workload(&cfg);
        for (a, b) in arr.iter().zip(&base) {
            assert_eq!(a.req.id, b.id);
            assert_eq!(a.req.tokens, b.tokens);
        }
    }

    #[test]
    fn base_payloads_match_generate_workload_and_times_are_sorted() {
        let cfg = open_cfg("poisson:1.5+followups:0");
        let arr = generate_arrivals(&cfg);
        assert_eq!(arr.len(), cfg.requests);
        assert!(arr.windows(2).all(|w| w[0].at <= w[1].at));
        let base = generate_workload(&cfg);
        for (a, b) in arr.iter().zip(&base) {
            assert_eq!(a.req.id, b.id);
            assert_eq!(a.req.tokens, b.tokens);
            assert_eq!(a.req.decode, b.decode);
            assert_eq!(a.req.tenant, b.tenant);
        }
    }

    #[test]
    fn bursts_inject_extra_arrivals_inside_their_windows() {
        let cfg = open_cfg("poisson:1.0+burst:10:20:5+followups:0");
        let arr = generate_arrivals(&cfg);
        assert!(arr.len() > cfg.requests,
                "burst injected extras: {}", arr.len());
        let injected: Vec<_> = arr
            .iter()
            .filter(|a| a.req.id > cfg.requests as u64)
            .collect();
        assert!(!injected.is_empty());
        for a in &injected {
            assert!(a.at >= 10.0 && a.at < 30.0, "at {}", a.at);
            assert_eq!(a.phase, ArrivalPhase::Burst);
        }
    }

    #[test]
    fn followups_carry_the_parents_warm_prefix() {
        let cfg = open_cfg("poisson:1.0+followups:100+think:5");
        let arr = generate_arrivals(&cfg);
        let by_id: std::collections::HashMap<u64, &TimedArrival> =
            arr.iter().map(|a| (a.req.id, a)).collect();
        let follows: Vec<_> =
            arr.iter().filter(|a| a.followup_of.is_some()).collect();
        assert_eq!(follows.len(), cfg.requests,
                   "every turn follows up at 100%");
        for f in follows {
            let parent = by_id[&f.followup_of.unwrap()];
            assert_eq!(f.req.id,
                       parent.req.id + FOLLOWUP_ID_BASE);
            assert!(f.at > parent.at, "re-arrives strictly later");
            assert_eq!(f.req.tenant, parent.req.tenant);
            // Warm prefix: parent prompt + the exact tokens the sim
            // will decode for it (900 + pos % 50 at position pos).
            let p = &parent.req;
            assert!(f.req.tokens.len() >= p.tokens.len() + p.decode);
            assert_eq!(&f.req.tokens[..p.tokens.len()], &p.tokens[..]);
            for (k, &tok) in f.req.tokens
                [p.tokens.len()..p.tokens.len() + p.decode]
                .iter()
                .enumerate()
            {
                assert_eq!(tok,
                           900 + ((p.tokens.len() + k) % 50) as i32);
            }
            assert!(f.req.tokens.len() + f.req.decode + 1
                        <= cfg.max_seq);
        }
    }

    #[test]
    fn same_seed_same_stream_different_seed_differs() {
        let cfg =
            open_cfg("diurnal:0.3:1.2:120+burst:30:20:4+followups:25");
        let a = generate_arrivals(&cfg);
        let b = generate_arrivals(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at.to_bits(), y.at.to_bits());
            assert_eq!(x.req.id, y.req.id);
            assert_eq!(x.req.tokens, y.req.tokens);
            assert_eq!(x.phase, y.phase);
            assert_eq!(x.followup_of, y.followup_of);
        }
        let other = ReplayConfig { seed: 99, ..cfg };
        let c = generate_arrivals(&other);
        assert!(a.iter().zip(&c).any(|(x, y)| {
            x.at.to_bits() != y.at.to_bits()
                || x.req.tokens != y.req.tokens
        }));
    }

    #[test]
    fn multi_tenant_open_loop_draws_zipf_popular_tenants() {
        let cfg = ReplayConfig {
            requests: 600,
            tenants: 5,
            arrivals: Some(
                ArrivalSpec::parse("poisson:2+followups:0+zipf:1.3")
                    .unwrap(),
            ),
            ..ReplayConfig::default()
        };
        let w = generate_workload(&cfg);
        let mut counts = vec![0usize; cfg.tenants];
        for r in &w {
            counts[r.tenant] += 1;
        }
        // Rank 0 dominates (Zipf), unlike the uniform draw.
        assert!(counts[0] > counts[4] * 2,
                "zipf head {counts:?}");
        assert!(counts.iter().all(|&c| c > 0));
    }
}
