//! Workload generators calibrated to the paper's Table 2.
//!
//! Each dataset row (HumanEval, MBPP, Fleurs, MSCOCO, Vizwiz, synthetic
//! HSTU) is described by its min/max/avg input and output sequence
//! lengths; samples are drawn from a truncated lognormal matched to
//! those statistics — the evaluation consumes only length
//! distributions, which Table 2 fully specifies (DESIGN.md
//! §Substitutions).
//!
//! [`arrivals`] layers fleet-scale *timing* on top: open-loop
//! Poisson/diurnal/burst arrival processes with Zipf tenant
//! populations and warm-prefix conversation follow-ups, feeding the
//! replay drivers timestamped requests instead of a pre-queued mix.

pub mod arrivals;
pub mod batchcfg;

use crate::models::TaskKind;
use crate::substrate::rng::Rng;

/// Length statistics for one modality stream (Table 2 row slice).
#[derive(Debug, Clone, Copy)]
pub struct LenStats {
    pub min: usize,
    pub max: usize,
    pub avg: f64,
}

impl LenStats {
    pub const fn new(min: usize, max: usize, avg: f64) -> Self {
        LenStats { min, max, avg }
    }

    /// Draw from a core-plus-tail mixture matched to (min, max, avg):
    /// with probability 1−q a normal around a core mean (clipped to the
    /// bounds), with probability q a uniform tail over [avg, max] — the
    /// long right tails of code-generation outputs (Table 2's 10k max)
    /// without dragging the mean.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        if self.min == self.max {
            return self.min;
        }
        const Q: f64 = 0.05;
        let tail_mean = (self.avg + self.max as f64) / 2.0;
        let core_mean =
            ((self.avg - Q * tail_mean) / (1.0 - Q)).max(self.min as f64);
        let x = if rng.f64() < Q {
            self.avg + rng.f64() * (self.max as f64 - self.avg)
        } else {
            core_mean + rng.normal() * (core_mean / 3.0)
        };
        (x.round() as i64)
            .clamp(self.min as i64, self.max as i64) as usize
    }
}

/// One Table-2 row: a (model, dataset, task) workload.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    pub task: TaskKind,
    pub dataset: &'static str,
    pub input: LenStats,
    pub output: LenStats,
    /// Average decode step count (paper's "Decode Step Count").
    pub decode_steps: f64,
    /// Paper-reported average per-sample latency on A100, ms (Table 2
    /// "Avg. Time"), used as reference in EXPERIMENTS.md comparisons.
    pub paper_avg_ms: f64,
}

/// The paper's Table 2 (averaged rows; T-T uses HumanEval as the primary
/// dataset and MBPP is listed separately).
pub const TABLE2: [WorkloadSpec; 10] = [
    WorkloadSpec {
        task: TaskKind::TextToText,
        dataset: "HumanEval",
        input: LenStats::new(44, 430, 154.0),
        output: LenStats::new(55, 10_000, 692.0),
        decode_steps: 538.0,
        paper_avg_ms: 4494.0,
    },
    WorkloadSpec {
        task: TaskKind::TextToText,
        dataset: "MBPP",
        input: LenStats::new(29, 1748, 59.0),
        output: LenStats::new(38, 10_000, 1076.0),
        decode_steps: 1016.0,
        paper_avg_ms: 5567.0,
    },
    WorkloadSpec {
        task: TaskKind::SpeechToSpeech,
        dataset: "Fleurs",
        input: LenStats::new(179, 1464, 493.0),
        output: LenStats::new(129, 1029, 385.0),
        decode_steps: 35.0,
        paper_avg_ms: 1578.0,
    },
    WorkloadSpec {
        task: TaskKind::SpeechToText,
        dataset: "Fleurs",
        input: LenStats::new(179, 1464, 493.0),
        output: LenStats::new(15, 98, 36.0),
        decode_steps: 30.0,
        paper_avg_ms: 1321.0,
    },
    WorkloadSpec {
        task: TaskKind::TextToSpeech,
        dataset: "Fleurs",
        input: LenStats::new(12, 80, 31.0),
        output: LenStats::new(145, 1030, 393.0),
        decode_steps: 34.0,
        paper_avg_ms: 1432.0,
    },
    WorkloadSpec {
        task: TaskKind::TextToTextTrans,
        dataset: "Fleurs",
        input: LenStats::new(12, 80, 31.0),
        output: LenStats::new(14, 95, 35.0),
        decode_steps: 34.0,
        paper_avg_ms: 1187.0,
    },
    WorkloadSpec {
        task: TaskKind::ImageToText,
        dataset: "MSCOCO",
        input: LenStats::new(1030, 1030, 1030.0),
        output: LenStats::new(30, 30, 30.0),
        decode_steps: 30.0,
        paper_avg_ms: 2913.0,
    },
    WorkloadSpec {
        task: TaskKind::ImageTextToText,
        dataset: "Vizwiz",
        input: LenStats::new(1033, 1095, 1040.0),
        output: LenStats::new(10, 10, 10.0),
        decode_steps: 10.0,
        paper_avg_ms: 1253.0,
    },
    WorkloadSpec {
        task: TaskKind::TextToImage,
        dataset: "MSCOCO",
        input: LenStats::new(10, 22, 13.9),
        output: LenStats::new(1025, 1025, 1025.0),
        decode_steps: 1024.0,
        paper_avg_ms: 159_702.0,
    },
    WorkloadSpec {
        task: TaskKind::HistoryToAction,
        dataset: "Synthetic",
        input: LenStats::new(4507, 5121, 4814.0),
        output: LenStats::new(4507, 5121, 4813.9),
        decode_steps: 0.0,
        paper_avg_ms: 50.0,
    },
];

/// Find the primary Table-2 row for a task.
pub fn spec_for(task: TaskKind) -> &'static WorkloadSpec {
    TABLE2
        .iter()
        .find(|w| w.task == task)
        .expect("every task has a Table-2 row")
}

/// One sampled workload item (paper-scale lengths).
#[derive(Debug, Clone)]
pub struct WorkItemSample {
    pub input_len: usize,
    pub output_len: usize,
}

/// Draw `n` samples from a workload spec.
pub fn sample_workload(spec: &WorkloadSpec, n: usize, seed: u64)
                       -> Vec<WorkItemSample> {
    let mut rng = Rng::new(seed ^ 0x9d2c_5680);
    (0..n)
        .map(|_| WorkItemSample {
            input_len: spec.input.sample(&mut rng),
            output_len: spec.output.sample(&mut rng),
        })
        .collect()
}

/// Generate synthetic HSTU user histories (random item ids, lengths from
/// the spec) — the paper's synthetic dataset (§3.1: random indices in
/// [0, 6000)).
pub fn hstu_histories(n: usize, max_len: usize, seed: u64) -> Vec<Vec<i32>> {
    let spec = spec_for(TaskKind::HistoryToAction);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = spec.input.sample(&mut rng).min(max_len).max(1);
            (0..len).map(|_| rng.range(0, 6000) as i32).collect()
        })
        .collect()
}

/// Summary statistics over sampled lengths (Tab-2 regeneration).
pub fn stats(xs: &[usize]) -> (usize, usize, f64) {
    let min = xs.iter().copied().min().unwrap_or(0);
    let max = xs.iter().copied().max().unwrap_or(0);
    let avg = xs.iter().sum::<usize>() as f64 / xs.len().max(1) as f64;
    (min, max, avg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_respect_bounds() {
        let mut rng = Rng::new(1);
        let s = LenStats::new(10, 100, 30.0);
        for _ in 0..2000 {
            let x = s.sample(&mut rng);
            assert!((10..=100).contains(&x));
        }
    }

    #[test]
    fn sample_mean_tracks_avg() {
        for spec in &TABLE2 {
            let xs: Vec<usize> = sample_workload(spec, 4000, 7)
                .into_iter()
                .map(|s| s.input_len)
                .collect();
            let (_, _, avg) = stats(&xs);
            let rel = (avg - spec.input.avg).abs() / spec.input.avg;
            assert!(
                rel < 0.35,
                "{} {}: avg {avg} vs {}",
                spec.dataset,
                spec.task,
                spec.input.avg
            );
        }
    }

    #[test]
    fn fixed_length_rows_are_constant() {
        let it = spec_for(TaskKind::ImageToText);
        let xs = sample_workload(it, 50, 3);
        assert!(xs.iter().all(|s| s.input_len == 1030));
    }

    #[test]
    fn hstu_histories_in_range() {
        let hs = hstu_histories(20, 1024, 5);
        assert_eq!(hs.len(), 20);
        for h in hs {
            assert!(!h.is_empty() && h.len() <= 1024);
            assert!(h.iter().all(|&i| (0..6000).contains(&i)));
        }
    }

    #[test]
    fn every_task_has_a_row() {
        for t in TaskKind::all() {
            let _ = spec_for(t);
        }
    }
}
