//! Shared helpers for the figure/table benches: the paper-scale task
//! specs keyed by the Table-2 workloads.

#![allow(dead_code)]

use mmserve::models::TaskKind;
use mmserve::perfmodel::configs::{CHAMELEON_34B, CHAMELEON_7B, HSTU_14L,
                                  LLAMA_34B, LLAMA_7B, SEAMLESS_M4T};
use mmserve::perfmodel::latency::TaskSpec;
use mmserve::workload::{spec_for, WorkloadSpec};

/// Paper-scale spec for one Table-2 workload at a given batch.
pub fn task_spec(task: TaskKind, batch: usize) -> TaskSpec {
    let w: &WorkloadSpec = spec_for(task);
    match task {
        TaskKind::TextToText => TaskSpec::Decoder {
            cfg: &LLAMA_34B,
            batch,
            prompt_len: w.input.avg as usize,
            decode_steps: w.decode_steps as usize,
            decodes_per_step: 1,
        },
        TaskKind::ImageToText | TaskKind::ImageTextToText => {
            TaskSpec::Decoder {
                cfg: &CHAMELEON_34B,
                batch,
                prompt_len: w.input.avg as usize,
                decode_steps: w.decode_steps as usize,
                decodes_per_step: 1,
            }
        }
        TaskKind::TextToImage => TaskSpec::Decoder {
            cfg: &CHAMELEON_34B,
            batch,
            prompt_len: w.input.avg as usize,
            decode_steps: w.decode_steps as usize,
            decodes_per_step: 2,
        },
        TaskKind::SpeechToSpeech
        | TaskKind::SpeechToText
        | TaskKind::TextToTextTrans
        | TaskKind::TextToSpeech => TaskSpec::Seamless {
            cfg: &SEAMLESS_M4T,
            src_len: w.input.avg as usize,
            text_steps: w.decode_steps as usize,
            speech_out: matches!(task, TaskKind::SpeechToSpeech
                                 | TaskKind::TextToSpeech),
            reorder_fused: false,
            speech_in: matches!(task, TaskKind::SpeechToSpeech
                                | TaskKind::SpeechToText),
        },
        TaskKind::HistoryToAction => TaskSpec::Hstu {
            cfg: &HSTU_14L,
            batch,
            seq: w.input.avg as usize,
        },
    }
}

/// 7B-class spec (LayerSkip figures use 7B and 34B).
pub fn task_spec_7b(task: TaskKind, batch: usize) -> TaskSpec {
    match task_spec(task, batch) {
        TaskSpec::Decoder {
            batch,
            prompt_len,
            decode_steps,
            decodes_per_step,
            ..
        } => {
            let cfg = match task.model() {
                mmserve::models::ModelKind::Chameleon => &CHAMELEON_7B,
                _ => &LLAMA_7B,
            };
            TaskSpec::Decoder {
                cfg,
                batch,
                prompt_len,
                decode_steps,
                decodes_per_step,
            }
        }
        other => other,
    }
}

/// Paper Table-3 max batch sizes (used as the "maximum batch" setting).
pub fn paper_max_batch(task: TaskKind) -> usize {
    match task {
        TaskKind::TextToText => 4,
        TaskKind::ImageToText | TaskKind::ImageTextToText
        | TaskKind::TextToImage => 16,
        TaskKind::SpeechToSpeech | TaskKind::SpeechToText => 128,
        TaskKind::TextToTextTrans | TaskKind::TextToSpeech => 384,
        TaskKind::HistoryToAction => 32,
    }
}

/// Whether real-artifact benches should run (artifacts present).
pub fn artifacts_available() -> Option<std::path::PathBuf> {
    let dir = mmserve::artifacts_dir();
    if dir.join("llama").join("manifest.json").exists() {
        Some(dir)
    } else {
        println!("  (artifacts not built — real-CPU sections skipped)");
        None
    }
}
