//! Figure 5: SDPA and SDPA+torch.compile speedups for Llama and
//! Chameleon at bs=1 and max batch — device model, PLUS the same levers
//! measured for real on the CPU-served tiny models (the directionally
//! honest part).

mod common;

use mmserve::coordinator::decoder_loop::DecoderSession;
use mmserve::coordinator::opts::{AttnImpl, ExecMode, OptConfig};
use mmserve::coordinator::request::SamplingParams;
use mmserve::models::TaskKind;
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::latency::task_cost;
use mmserve::perfmodel::levers::Levers;
use mmserve::runtime::engine::Engine;
use mmserve::substrate::bench::{geomean, BenchSuite};
use mmserve::substrate::table::Table;

fn main() {
    device_model_part();
    real_cpu_part();
}

fn device_model_part() {
    println!("=== Figure 5 (device model): SDPA / +compile speedups, \
              Llama & Chameleon, A100 ===");
    let tasks = [TaskKind::TextToText, TaskKind::ImageToText,
                 TaskKind::TextToImage, TaskKind::ImageTextToText];
    let mut t = Table::new(&[
        "task", "batch", "sdpa", "sdpa+compile",
    ]);
    let mut sdpa_speedups = vec![];
    let mut cmp_speedups = vec![];
    for task in tasks {
        for batch in [1usize, common::paper_max_batch(task)] {
            let spec = common::task_spec(task, batch);
            let base = task_cost(&spec, &A100, &Levers::baseline()).total;
            let sdpa = task_cost(&spec, &A100, &Levers::sdpa()).total;
            let cmp = task_cost(&spec, &A100, &Levers::sdpa_compile()).total;
            t.row(&[
                task.notation().to_string(),
                format!("{batch}"),
                format!("{:.2}x", base / sdpa),
                format!("{:.2}x", base / cmp),
            ]);
            sdpa_speedups.push(base / sdpa);
            cmp_speedups.push(base / cmp);
        }
    }
    t.print();
    println!(
        "geomean: sdpa {:.2}x, sdpa+compile {:.2}x  \
         (paper: ~1.07–1.43x sdpa; 2.28–3.09x total with compile)",
        geomean(&sdpa_speedups),
        geomean(&cmp_speedups)
    );
}

fn real_cpu_part() {
    let Some(dir) = common::artifacts_available() else { return };
    println!("\n=== Figure 5 (real CPU, tiny Llama): measured lever \
              effects ===");
    let engine = Engine::load(&dir.join("llama")).expect("engine");
    let mut suite = BenchSuite::new("llama tiny: 16-token greedy decode");
    let prompt: Vec<i32> = (1..20).collect();
    let sp = SamplingParams::greedy();

    let run = |opt: OptConfig| {
        let session = DecoderSession::new(&engine, opt).expect("session");
        let p = prompt.clone();
        move || {
            let r = session.generate(&p, 16, &sp).expect("gen");
            assert!(!r.tokens.is_empty());
        }
    };
    suite.bench("baseline (eager per-op dispatch)",
                run(OptConfig::eager_baseline()));
    suite.bench("graph (compile+CUDA-Graph analogue)",
                run(OptConfig::baseline()));
    suite.bench("graph+flash (SDPA lever)", run(OptConfig::sdpa()));
    suite.bench("graph+flash+int8wo (Sys-Opt)", {
        let mut o = OptConfig::sys_opt();
        // flash+int8 combined stage exists as decode_b1_flash_int8wo
        o.attn = AttnImpl::Flash;
        run(o)
    });
    suite.speedup("compile/graph vs eager",
                  "baseline (eager per-op dispatch)",
                  "graph (compile+CUDA-Graph analogue)");
    suite.speedup("all system levers vs eager",
                  "baseline (eager per-op dispatch)",
                  "graph+flash+int8wo (Sys-Opt)");
    let _ = ExecMode::Graph;
}
