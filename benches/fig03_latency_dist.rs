//! Figure 3: end-to-end latency distributions per workload — per-sample
//! lengths drawn from the Table-2 generators, costed by the device
//! model at bs=1 on A100 (the paper's Fig-3 methodology).

mod common;

use mmserve::models::TaskKind;
use mmserve::perfmodel::configs::{CHAMELEON_34B, HSTU_14L, LLAMA_34B,
                                  SEAMLESS_M4T};
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::latency::{task_cost, TaskSpec};
use mmserve::perfmodel::levers::Levers;
use mmserve::substrate::metrics::Histogram;
use mmserve::substrate::table::Table;
use mmserve::workload::{sample_workload, TABLE2};

fn main() {
    println!("=== Figure 3: latency distribution per workload \
              (A100, bs=1, device model) ===");
    let n = if std::env::var("MMSERVE_BENCH_FAST").is_ok() { 30 } else { 120 };
    let mut t = Table::new(&[
        "task", "dataset", "p10(ms)", "p50(ms)", "p90(ms)", "mean(ms)",
        "stddev", "paper avg(ms)",
    ]);
    for w in &TABLE2 {
        let mut h = Histogram::new();
        for s in sample_workload(w, n, 7) {
            let spec = match w.task {
                TaskKind::TextToText => TaskSpec::Decoder {
                    cfg: &LLAMA_34B,
                    batch: 1,
                    prompt_len: s.input_len,
                    decode_steps: s.output_len.min(1200),
                    decodes_per_step: 1,
                },
                TaskKind::ImageToText | TaskKind::ImageTextToText => {
                    TaskSpec::Decoder {
                        cfg: &CHAMELEON_34B,
                        batch: 1,
                        prompt_len: s.input_len,
                        decode_steps: w.decode_steps as usize,
                        decodes_per_step: 1,
                    }
                }
                TaskKind::TextToImage => TaskSpec::Decoder {
                    cfg: &CHAMELEON_34B,
                    batch: 1,
                    prompt_len: s.input_len,
                    decode_steps: 1024,
                    decodes_per_step: 2,
                },
                TaskKind::SpeechToSpeech
                | TaskKind::SpeechToText
                | TaskKind::TextToTextTrans
                | TaskKind::TextToSpeech => TaskSpec::Seamless {
                    cfg: &SEAMLESS_M4T,
                    src_len: s.input_len,
                    text_steps: w.decode_steps as usize,
                    speech_out: matches!(w.task, TaskKind::SpeechToSpeech
                                         | TaskKind::TextToSpeech),
                    reorder_fused: false,
                    speech_in: matches!(w.task, TaskKind::SpeechToSpeech
                                        | TaskKind::SpeechToText),
                },
                TaskKind::HistoryToAction => TaskSpec::Hstu {
                    cfg: &HSTU_14L,
                    batch: 1,
                    seq: s.input_len,
                },
            };
            let c = task_cost(&spec, &A100, &Levers::baseline());
            h.record(c.total * 1e3);
        }
        t.row(&[
            w.task.notation().to_string(),
            w.dataset.to_string(),
            format!("{:.1}", h.percentile(10.0)),
            format!("{:.1}", h.percentile(50.0)),
            format!("{:.1}", h.percentile(90.0)),
            format!("{:.1}", h.mean()),
            format!("{:.1}", h.stddev()),
            format!("{:.0}", w.paper_avg_ms),
        ]);
    }
    t.print();
    println!("\npaper shape check: T-T widest spread (stddev), T-I the \
              longest latency, H-A the shortest.");
}
