//! Table 2: sequence-length distributions — regenerate min/max/avg from
//! the calibrated workload generators and compare to the paper's values.

use mmserve::substrate::table::Table;
use mmserve::workload::{sample_workload, stats, TABLE2};

fn main() {
    println!("=== Table 2: sequence-length distributions \
              (generated vs paper) ===");
    let mut t = Table::new(&[
        "task", "dataset", "in min/max/avg (gen)", "in avg (paper)",
        "out min/max/avg (gen)", "out avg (paper)",
    ]);
    for spec in &TABLE2 {
        let samples = sample_workload(spec, 2000, 42);
        let ins: Vec<usize> = samples.iter().map(|s| s.input_len).collect();
        let outs: Vec<usize> = samples.iter().map(|s| s.output_len).collect();
        let (imin, imax, iavg) = stats(&ins);
        let (omin, omax, oavg) = stats(&outs);
        t.row(&[
            spec.task.notation().to_string(),
            spec.dataset.to_string(),
            format!("{imin}/{imax}/{iavg:.0}"),
            format!("{:.0}", spec.input.avg),
            format!("{omin}/{omax}/{oavg:.0}"),
            format!("{:.0}", spec.output.avg),
        ]);
    }
    t.print();
}
