//! Figure 1: per-task system requirements (latency, GPU utilization,
//! memory capacity, compute) — the radar chart as a table.

mod common;

use mmserve::models::TaskKind;
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::requirements::requirements;
use mmserve::substrate::table::{fmt_bytes, Table};

fn main() {
    println!("=== Figure 1: system requirements per task (A100, bs=1, \
              device model) ===");
    let mut t = Table::new(&[
        "task", "model", "latency(ms)", "gpu_util", "memory", "compute(GF)",
    ]);
    for task in TaskKind::all() {
        let spec = common::task_spec(task, 1);
        let r = requirements(task.notation(), &spec, &A100,
                             &Levers::baseline());
        t.row(&[
            task.notation().to_string(),
            format!("{:?}", task.model()),
            format!("{:.1}", r.latency_s * 1e3),
            format!("{:.0}%", r.gpu_utilization * 100.0),
            fmt_bytes(r.memory_bytes),
            format!("{:.1}", r.compute_flops / 1e9),
        ]);
    }
    t.print();
    println!("\npaper shape check: T-I demands the most across all axes; \
              HSTU has the highest GPU utilization.");
}
