//! Figure 4: operator time breakdown on A100 (prefill/decode phases,
//! with the GPU-Idle bucket) for the four model families — plus, when
//! artifacts are built, the *measured* counterpart from the telemetry
//! subsystem: a traced tiny-llama generation with its per-stage
//! dispatch times and idle-gap attribution.

mod common;

use mmserve::coordinator::decoder_loop::DecoderSession;
use mmserve::coordinator::opts::OptConfig;
use mmserve::coordinator::request::SamplingParams;
use mmserve::perfmodel::breakdown::render;
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::standard_breakdown_rows;
use mmserve::runtime::engine::Engine;
use mmserve::telemetry::{Tracer, TraceReport};

fn main() {
    println!("=== Figure 4: operator time breakdown (A100, max batch, \
              baseline) ===");
    let rows = standard_breakdown_rows(&A100, &Levers::baseline());
    println!("{}", render(&rows));
    println!("observation checks:");
    for b in &rows {
        for (phase, times) in &b.phase_times {
            let wall = times.total();
            let idle = times.get("Idle") / wall * 100.0;
            let lin = times.get("Linear") / wall * 100.0;
            let attn = times.get("Attention") / wall * 100.0;
            println!(
                "  {:<22} [{phase}] idle={idle:.0}% linear={lin:.0}% \
                 attention={attn:.0}%",
                b.label
            );
        }
    }
    println!("\npaper: decode idle dominates for Llama/CM3 (Obs #2); \
              Linear ≥ Attention for Llama/CM3 (Obs #3); Attention \
              dominates HSTU; KV_Reorder visible for Seamless (Obs #4).");

    if let Some(dir) = common::artifacts_available() {
        if let Err(e) = measured_breakdown(&dir) {
            println!("  (measured section failed: {e:#})");
        }
    }
}

/// The measured analogue over the real tiny model: trace a generation,
/// fold it into per-stage times + the idle-gap attribution, and print
/// it under the model projection for side-by-side comparison.
fn measured_breakdown(dir: &std::path::Path) -> anyhow::Result<()> {
    println!("\n=== measured (telemetry, tiny llama on CPU) ===");
    let tracer = Tracer::off();
    let mut engine = Engine::load(&dir.join("llama"))?;
    engine.set_tracer(tracer.worker("llama"));
    let session = DecoderSession::new(&engine, OptConfig::baseline())?;
    let prompt: Vec<i32> = (2..30).collect();
    session.generate(&prompt, 4, &SamplingParams::greedy())?; // warm
    tracer.set_enabled(true);
    session.generate(&prompt, 32, &SamplingParams::greedy())?;
    tracer.set_enabled(false);
    let trace = tracer.drain();
    let report = TraceReport::from_trace(&trace);
    println!("{}", report.render());
    Ok(())
}
