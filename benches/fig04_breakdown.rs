//! Figure 4: operator time breakdown on A100 (prefill/decode phases,
//! with the GPU-Idle bucket) for the four model families.

use mmserve::perfmodel::breakdown::render;
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::standard_breakdown_rows;

fn main() {
    println!("=== Figure 4: operator time breakdown (A100, max batch, \
              baseline) ===");
    let rows = standard_breakdown_rows(&A100, &Levers::baseline());
    println!("{}", render(&rows));
    println!("observation checks:");
    for b in &rows {
        for (phase, times) in &b.phase_times {
            let wall = times.total();
            let idle = times.get("Idle") / wall * 100.0;
            let lin = times.get("Linear") / wall * 100.0;
            let attn = times.get("Attention") / wall * 100.0;
            println!(
                "  {:<22} [{phase}] idle={idle:.0}% linear={lin:.0}% \
                 attention={attn:.0}%",
                b.label
            );
        }
    }
    println!("\npaper: decode idle dominates for Llama/CM3 (Obs #2); \
              Linear ≥ Attention for Llama/CM3 (Obs #3); Attention \
              dominates HSTU; KV_Reorder visible for Seamless (Obs #4).");
}
