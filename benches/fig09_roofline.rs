//! Figure 9: roofline analysis — arithmetic intensity vs achieved
//! FLOP/s per workload, baseline (circle) vs Sys-Opt (star).

mod common;

use mmserve::models::TaskKind;
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::roofline::{knee, point};
use mmserve::substrate::table::Table;

fn main() {
    println!("=== Figure 9: roofline (A100) — baseline ○ vs Sys-Opt ★ ===");
    println!("  device: peak {:.0} TFLOP/s (tensor), BW {:.2} TB/s, \
              knee at {:.0} FLOP/B\n",
             A100.peak_tensor / 1e12, A100.hbm_bw / 1e12, knee(&A100));
    let mut t = Table::new(&[
        "task", "cfg", "intensity (FLOP/B)", "perf (TFLOP/s)", "% of roof",
    ]);
    for task in TaskKind::all() {
        let spec = common::task_spec(task, 1);
        for (mark, lv) in [("○ base", Levers::baseline()),
                           ("★ sys-opt", Levers::sys_opt())] {
            let p = point(task.notation(), &spec, &A100, &lv);
            t.row(&[
                task.notation().to_string(),
                mark.to_string(),
                format!("{:.1}", p.intensity),
                format!("{:.2}", p.perf / 1e12),
                format!("{:.0}%", p.roof_frac * 100.0),
            ]);
        }
    }
    t.print();
    println!("\npaper shape check: every ★ sits up-and-right of its ○; \
              memory-bound tasks (T-T, T-I) gain the most; Seamless \
              moves the least (§4.4).");

    // Beyond-the-roofline deltas for Llama (paper §4.4 narrative):
    let spec = common::task_spec(TaskKind::TextToText, 1);
    let base = mmserve::perfmodel::latency::task_cost(
        &spec, &A100, &Levers::baseline());
    let sdpa = mmserve::perfmodel::latency::task_cost(
        &spec, &A100, &Levers::sdpa());
    let opt = mmserve::perfmodel::latency::task_cost(
        &spec, &A100, &Levers::sys_opt());
    println!(
        "\nLlama T-T deltas: SDPA flops {:+.1}% bytes {:+.1}% \
         (paper: +8% / −14%); AutoQuant bytes ÷{:.2} \
         (paper: ÷3.1 on weights)",
        (sdpa.flops / base.flops - 1.0) * 100.0,
        (sdpa.bytes / base.bytes - 1.0) * 100.0,
        sdpa.bytes / opt.bytes,
    );
}
