//! Figure 7 / Table 4: the Seamless incremental optimization ladder —
//! compile the text decoder, add CUDA-Graph, compile the KV reorder,
//! compile the vocoder — plus the real-CPU measured reorder disciplines
//! (host copy vs fused gather, Obs #4).

mod common;

use mmserve::coordinator::seamless_pipe::{ReorderMode, SeamlessPipeline,
                                          SeamlessTask};
use mmserve::perfmodel::configs::SEAMLESS_M4T;
use mmserve::perfmodel::device::{DeviceSpec, A100};
use mmserve::perfmodel::levers::cost_walk;
use mmserve::perfmodel::ops::{self, AttnKind, OpWalk};
use mmserve::runtime::engine::Engine;
use mmserve::substrate::bench::BenchSuite;
use mmserve::workload::spec_for;

/// Cost the S-S pipeline with per-module compile toggles (the Fig-7
/// ladder): (text_dec_compiled, reorder_compiled, vocoder_compiled).
fn ladder_cost(dev: &DeviceSpec, dec_c: bool, reorder_c: bool,
               voc_c: bool) -> f64 {
    let cfg = &SEAMLESS_M4T;
    let w = spec_for(mmserve::models::TaskKind::SpeechToSpeech);
    let src = w.input.avg as usize;
    let steps = w.decode_steps as usize;
    let enc = ops::seamless_encoder(cfg, src, AttnKind::Naive);
    let (enc_wall, _) = cost_walk(&enc, dev, false);

    let mut dec = OpWalk::default();
    let mut reorder = OpWalk::default();
    for i in 0..steps {
        dec.extend(ops::seamless_dec_step(cfg, cfg.beam, i + 1, src,
                                          AttnKind::Naive));
        reorder.extend(ops::seamless_kv_reorder(cfg, cfg.beam, i + 1,
                                                reorder_c));
    }
    let (dec_wall, _) = cost_walk(&dec, dev, dec_c);
    let (re_wall, _) = cost_walk(&reorder, dev, reorder_c);

    let t2u = ops::seamless_t2u(cfg, steps);
    let (t2u_wall, _) = cost_walk(&t2u, dev, false);
    let voc = ops::seamless_vocoder(cfg, steps * cfg.t2u_upsample);
    let (voc_wall, _) = cost_walk(&voc, dev, voc_c);
    enc_wall + dec_wall + re_wall + t2u_wall + voc_wall
}

fn main() {
    println!("=== Figure 7 (device model): Seamless S-S incremental \
              compile ladder, A100 bs=1 ===");
    let base = ladder_cost(&A100, false, false, false);
    let steps: [(&str, f64); 5] = [
        ("baseline", base),
        ("[TextDec] compile+graph", ladder_cost(&A100, true, false, false)),
        ("+[KV reorder] compile", ladder_cost(&A100, true, true, false)),
        ("+[Vocoder] compile+graph", ladder_cost(&A100, true, true, true)),
        ("(paper end-to-end: 2.7x)", 0.0),
    ];
    for (label, cost) in &steps[..4] {
        println!("  {:<28} {:>9.1} ms   {:>5.2}x", label, cost * 1e3,
                 base / cost);
    }
    println!("  {}", steps[4].0);

    real_cpu_part();
}

fn real_cpu_part() {
    let Some(dir) = common::artifacts_available() else { return };
    println!("\n=== Obs #4 (real CPU, tiny Seamless): KV reorder \
              disciplines ===");
    let engine = Engine::load(&dir.join("seamless")).expect("engine");
    let wav: Vec<f32> = (0..160 * 40)
        .map(|i| (i as f32 * 0.02).sin() * 0.4)
        .collect();
    let mut suite = BenchSuite::new("seamless S-T (beam=4) full pipeline");
    for (label, mode) in [
        ("reorder=host_copy (baseline index_select)", ReorderMode::HostCopy),
        ("reorder=fused gather (compile'd copy_)", ReorderMode::Fused),
    ] {
        let pipe = SeamlessPipeline::new(&engine, mode).expect("pipe");
        let w = wav.clone();
        suite.bench(label, move || {
            let r = pipe
                .run(SeamlessTask::SpeechToText, Some(&w), None, 24)
                .expect("run");
            assert!(r.decode_steps > 0);
        });
    }
    suite.speedup("fused reorder vs host copy",
                  "reorder=host_copy (baseline index_select)",
                  "reorder=fused gather (compile'd copy_)");

    // Per-module time breakdown of one run (the Fig-4 Seamless bar).
    let pipe = SeamlessPipeline::new(&engine, ReorderMode::HostCopy)
        .expect("pipe");
    let r = pipe
        .run(SeamlessTask::SpeechToSpeech, Some(&wav), None, 24)
        .expect("run");
    println!("\n  per-module breakdown (S-S, host-copy reorder):");
    for (k, v) in r.times.entries() {
        println!("    {:<18} {:>8.2} ms", k, v * 1e3);
    }
}
