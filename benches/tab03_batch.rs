//! Table 3: maximum batch size per task fitting one A100-80GB — solved
//! from the memory model, compared to the paper's configuration, and
//! extended with the paged-allocation column (kvpool): pages sized to
//! the context the workload actually reaches instead of the worst case.

mod common;

use mmserve::kvpool::DEFAULT_PAGE_SIZE;
use mmserve::models::TaskKind;
use mmserve::perfmodel::device::A100;
use mmserve::substrate::table::{fmt_bytes, Table};
use mmserve::workload::batchcfg::{chunked_prefill_rows, max_batch,
                                  max_batch_paged, per_sample_bytes,
                                  weight_bytes};

fn main() {
    println!("=== Table 3: max batch size per task (A100-80GB solve) ===");
    let mut t = Table::new(&[
        "task", "weights", "per-sample", "max batch (solved)",
        "max batch (paper)", "max batch (paged)",
    ]);
    for task in TaskKind::all() {
        t.row(&[
            task.notation().to_string(),
            fmt_bytes(weight_bytes(task)),
            fmt_bytes(per_sample_bytes(task)),
            format!("{}", max_batch(task, &A100)),
            format!("{}", common::paper_max_batch(task)),
            format!("{}", max_batch_paged(task, &A100, DEFAULT_PAGE_SIZE)),
        ]);
    }
    t.print();
    println!("\nshape check: llama (34B weights + 10k-token KV) smallest; \
              seamless largest; ordering llama < chameleon < hstu < \
              seamless holds. The paged column is the kvpool headroom: \
              KV sized for reached context (avg input + decode steps, \
              page-rounded), which is what the pool's admission \
              actually spends.");

    // Chunked-vs-whole prefill interference projection: the worst
    // decode-tick stall one admission causes, and the TTFT price of
    // bounding it (one interleaved decode tick per chunk).
    const CHUNK: usize = 256;
    println!(
        "\n=== chunked prefill projection (chunk = {CHUNK} tokens, \
         A100) ==="
    );
    let mut t = Table::new(&[
        "task", "prompt", "chunks", "stall whole (ms)",
        "stall chunked (ms)", "p99-TTFT whole (ms)",
        "p99-TTFT chunked (ms)",
    ]);
    for r in chunked_prefill_rows(&A100, CHUNK) {
        t.row(&[
            r.task.notation().to_string(),
            r.prompt_len.to_string(),
            r.chunks.to_string(),
            format!("{:.2}", r.stall_whole_ms),
            format!("{:.2}", r.stall_chunked_ms),
            format!("{:.2}", r.ttft_whole_ms),
            format!("{:.2}", r.ttft_chunked_ms),
        ]);
    }
    t.print();
    println!("\nchunked prefill bounds the decode-tick stall by the \
              marginal cost of one chunk instead of a whole prompt; \
              TTFT regresses by at most one decode tick per chunk \
              (the acceptance bound).");
}
