//! Table 3: maximum batch size per task fitting one A100-80GB — solved
//! from the memory model, compared to the paper's configuration, and
//! extended with the paged-allocation column (kvpool): pages sized to
//! the context the workload actually reaches instead of the worst case.

mod common;

use mmserve::kvpool::DEFAULT_PAGE_SIZE;
use mmserve::models::TaskKind;
use mmserve::perfmodel::device::A100;
use mmserve::substrate::table::{fmt_bytes, Table};
use mmserve::workload::batchcfg::{max_batch, max_batch_paged,
                                  per_sample_bytes, weight_bytes};

fn main() {
    println!("=== Table 3: max batch size per task (A100-80GB solve) ===");
    let mut t = Table::new(&[
        "task", "weights", "per-sample", "max batch (solved)",
        "max batch (paper)", "max batch (paged)",
    ]);
    for task in TaskKind::all() {
        t.row(&[
            task.notation().to_string(),
            fmt_bytes(weight_bytes(task)),
            fmt_bytes(per_sample_bytes(task)),
            format!("{}", max_batch(task, &A100)),
            format!("{}", common::paper_max_batch(task)),
            format!("{}", max_batch_paged(task, &A100, DEFAULT_PAGE_SIZE)),
        ]);
    }
    t.print();
    println!("\nshape check: llama (34B weights + 10k-token KV) smallest; \
              seamless largest; ordering llama < chameleon < hstu < \
              seamless holds. The paged column is the kvpool headroom: \
              KV sized for reached context (avg input + decode steps, \
              page-rounded), which is what the pool's admission \
              actually spends.");
}
