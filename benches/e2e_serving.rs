//! End-to-end serving benchmark on the real tiny models (CPU PJRT):
//! continuous-batched Llama throughput under each lever configuration,
//! plus Seamless and HSTU service latency. This is the "whole stack
//! composes" measurement recorded in EXPERIMENTS.md.

mod common;

use std::time::Instant;

use mmserve::coordinator::opts::{ExecMode, OptConfig};
use mmserve::coordinator::request::{Request, RequestInput, SamplingParams};
use mmserve::coordinator::seamless_pipe::ReorderMode;
use mmserve::coordinator::server::{collect_stats, Router, RouterConfig};
use mmserve::kvpool::KvPoolConfig;
use mmserve::models::{ModelKind, TaskKind};
use mmserve::routing::RoutingPolicy;

fn main() {
    let Some(dir) = common::artifacts_available() else { return };
    let fast = std::env::var("MMSERVE_BENCH_FAST").is_ok();
    let n_req = if fast { 6 } else { 16 };
    let max_new = if fast { 8 } else { 16 };

    println!("=== E2E serving (real CPU, tiny models) ===");
    // ---- Llama under lever configs -----------------------------------
    for (label, opt, batch) in [
        ("llama eager bs=1 (launch-overhead baseline)",
         OptConfig::eager_baseline(), 1usize),
        ("llama graph bs=1", OptConfig::baseline(), 1),
        ("llama graph bs=4 (continuous batching)", OptConfig::baseline(), 4),
        ("llama graph+flash bs=4", OptConfig::sdpa(), 4),
        ("llama graph+flash+int8 bs=4", OptConfig::sys_opt(), 4),
        ("llama layerskip bs=1", {
            let mut o = OptConfig::baseline();
            o.layerskip = true;
            o
        }, 1),
    ] {
        let router = Router::start(&dir, RouterConfig {
            models: vec![ModelKind::Llama],
            opt,
            reorder: ReorderMode::Fused,
            batch,
            prefill_budget: 0,
            chunk_prefill: 0,
            kv: KvPoolConfig::default(),
            tracer: None,
            ..RouterConfig::default()
        });
        // warm: one request compiles the stages
        let _ = router.call(Request::text(router.fresh_id(),
                                          TaskKind::TextToText, "warm", 2));
        let t0 = Instant::now();
        let mut rxs = vec![];
        for i in 0..n_req {
            let mut req = Request::text(
                router.fresh_id(),
                TaskKind::TextToText,
                ["sort an array", "hello world function",
                 "binary search impl", "compute a checksum"][i % 4],
                max_new,
            );
            req.sampling = SamplingParams::greedy();
            rxs.push(router.submit(req).expect("submit"));
        }
        let responses: Vec<_> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap())
            .collect();
        let stats = collect_stats(&responses, t0.elapsed().as_secs_f64());
        println!(
            "  {:<44} {:>7.1} tok/s  p50-ttft {:>7.2} ms  p50-e2e \
             {:>8.2} ms",
            label,
            stats.throughput_tok_s(),
            stats.ttft.percentile(50.0),
            stats.e2e.percentile(50.0)
        );
        router.shutdown();
        let _ = ExecMode::Graph;
    }

    // ---- Chunked vs whole-prefill under a long-prompt mix --------------
    // Mean TBT (tpot) should improve with chunking — long admissions no
    // longer stack a whole prompt's prefill into one decode tick — while
    // p99 TTFT may regress by at most the chunk count's one-tick bound.
    println!("\n  chunked vs whole prefill (long-prompt mix):");
    let long_prompt =
        "characterize and accelerate multimodal generation inference "
            .repeat(12);
    for (label, chunk) in
        [("whole-prompt admission", 0usize), ("chunk-prefill 32", 32)]
    {
        let router = Router::start(&dir, RouterConfig {
            models: vec![ModelKind::Llama],
            opt: OptConfig::baseline(),
            reorder: ReorderMode::Fused,
            batch: 4,
            prefill_budget: 0,
            chunk_prefill: chunk,
            kv: KvPoolConfig::default(),
            tracer: None,
            ..RouterConfig::default()
        });
        let _ = router.call(Request::text(router.fresh_id(),
                                          TaskKind::TextToText, "warm", 2));
        let t0 = Instant::now();
        let mut rxs = vec![];
        for i in 0..n_req {
            let text = if i % 2 == 0 {
                long_prompt.as_str()
            } else {
                "short chat turn"
            };
            let mut req = Request::text(router.fresh_id(),
                                        TaskKind::TextToText, text,
                                        max_new);
            req.sampling = SamplingParams::greedy();
            rxs.push(router.submit(req).expect("submit"));
        }
        let responses: Vec<_> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap())
            .collect();
        let stats = collect_stats(&responses, t0.elapsed().as_secs_f64());
        println!(
            "  {:<44} mean-tbt {:>7.2} ms  p99-ttft {:>8.2} ms  p50-e2e \
             {:>8.2} ms",
            label,
            stats.tpot.mean(),
            stats.ttft.percentile(99.0),
            stats.e2e.percentile(50.0)
        );
        router.shutdown();
    }

    // ---- Prefix-aware routing across 2 replicas ------------------------
    // Shared-system-prompt workload on real replicated workers:
    // prefix-affinity steers same-prefix requests to the replica whose
    // pool already holds their blocks, so the fleet prefix hit rate
    // rises vs. round-robin spray (KV reuse across replicas is a
    // first-order serving lever); TTFT shows the load-concentration
    // tradeoff.
    println!("\n  prefix-aware routing (2 replicas, shared system prompt):");
    let system_prompt =
        "you are a concise multimodal serving assistant for code "
            .repeat(3);
    for (label, policy) in [
        ("round-robin", RoutingPolicy::RoundRobin),
        ("prefix-affinity", RoutingPolicy::PrefixAffinity),
    ] {
        let router = Router::start(&dir, RouterConfig {
            models: vec![ModelKind::Llama],
            batch: 4,
            replicas: 2,
            policy,
            ..RouterConfig::default()
        });
        // Warm both replicas: the router bumps the queued gauge
        // synchronously before each send and the workers are still
        // loading their engines at this point (they cannot dequeue
        // yet), so depth-aware routing deterministically spreads the
        // pair — one warm request per replica.
        let warm: Vec<_> = (0..2)
            .map(|_| {
                router
                    .submit(Request::text(router.fresh_id(),
                                          TaskKind::TextToText, "warm", 2))
                    .expect("submit")
            })
            .collect();
        for rx in warm {
            let _ = rx.recv().unwrap();
        }
        let t0 = Instant::now();
        let mut rxs = vec![];
        for i in 0..n_req {
            let text =
                format!("{system_prompt} task {i}: reverse a string");
            let mut req = Request::text(router.fresh_id(),
                                        TaskKind::TextToText, &text,
                                        max_new);
            req.sampling = SamplingParams::greedy();
            rxs.push(router.submit(req).expect("submit"));
        }
        let responses: Vec<_> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap())
            .collect();
        let stats = collect_stats(&responses, t0.elapsed().as_secs_f64());
        let reports = router.replica_reports();
        let (hits, lookups) =
            reports.iter().fold((0u64, 0u64), |(h, l), r| {
                (h + r.prefix_hits, l + r.prefix_lookups)
            });
        let rate = if lookups == 0 {
            0.0
        } else {
            hits as f64 / lookups as f64 * 100.0
        };
        println!(
            "  {:<44} fleet hit-rate {:>5.1}%  p50-ttft {:>7.2} ms  \
             routed {}",
            label,
            rate,
            stats.ttft.percentile(50.0),
            reports
                .iter()
                .map(|r| r.routed.to_string())
                .collect::<Vec<_>>()
                .join("/"),
        );
        router.shutdown();
    }

    // ---- Multimodal mixed batch ---------------------------------------
    println!("\n  mixed multimodal batch (all four models):");
    let router = Router::start(&dir, RouterConfig {
        models: vec![ModelKind::Llama, ModelKind::Chameleon,
                     ModelKind::Seamless, ModelKind::Hstu],
        opt: OptConfig::baseline(),
        reorder: ReorderMode::Fused,
        batch: 4,
        prefill_budget: 0,
        chunk_prefill: 0,
        kv: KvPoolConfig::default(),
        tracer: None,
        ..RouterConfig::default()
    });
    let wav: Vec<f32> = (0..160 * 30).map(|i| (i as f32 * 0.03).sin())
        .collect();
    let px = vec![0.3f32; 64 * 64];
    let history: Vec<i32> = (0..200).map(|i| (i * 37) % 6000).collect();
    let t0 = Instant::now();
    let reqs: Vec<Request> = vec![
        Request::text(router.fresh_id(), TaskKind::TextToText,
                      "write a parser", max_new),
        Request {
            id: router.fresh_id(),
            task: TaskKind::ImageToText,
            input: RequestInput::Image { pixels: px.clone(), h: 64, w: 64 },
            max_new_tokens: 8,
            sampling: SamplingParams::greedy(),
        },
        Request {
            id: router.fresh_id(),
            task: TaskKind::SpeechToText,
            input: RequestInput::Speech(wav),
            max_new_tokens: 12,
            sampling: SamplingParams::greedy(),
        },
        Request {
            id: router.fresh_id(),
            task: TaskKind::HistoryToAction,
            input: RequestInput::History(history),
            max_new_tokens: 0,
            sampling: SamplingParams::greedy(),
        },
    ];
    let rxs: Vec<_> = reqs
        .into_iter()
        .map(|r| (r.task, router.submit(r).unwrap()))
        .collect();
    for (task, rx) in rxs {
        let r = rx.recv().unwrap().expect("response");
        println!("    {:<6} e2e {:>8.2} ms  ({} decode steps)",
                 task.notation(), r.e2e * 1e3, r.decode_steps);
    }
    println!("  mixed-batch wall: {:.2} s", t0.elapsed().as_secs_f64());
    router.shutdown();
}
