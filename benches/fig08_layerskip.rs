//! Figure 8: LayerSkip speedups at bs=1 (device model for the paper
//! models + real-CPU measured self-speculative decoding on the tiny
//! model), and the "putting it altogether" cross-stack geomean
//! (paper: 1.58x LayerSkip alone → 3.88x with all levers).

mod common;

use mmserve::coordinator::decoder_loop::DecoderSession;
use mmserve::coordinator::opts::OptConfig;
use mmserve::coordinator::request::SamplingParams;
use mmserve::models::TaskKind;
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::latency::{layerskip_speedup, task_cost,
                                  LAYERSKIP_ACCEPT};
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::configs::{CHAMELEON_7B, LLAMA_34B, LLAMA_7B};
use mmserve::runtime::engine::Engine;
use mmserve::substrate::bench::{geomean, BenchSuite};

fn main() {
    device_model_part();
    real_cpu_part();
}

fn device_model_part() {
    println!("=== Figure 8 (device model): LayerSkip bs=1 speedups ===");
    let rows = [
        ("CodeLlama-7B  T-T", TaskKind::TextToText, true),
        ("CodeLlama-34B T-T", TaskKind::TextToText, false),
        ("Chameleon-7B  I-T", TaskKind::ImageToText, true),
        ("Chameleon-7B  IT-T", TaskKind::ImageTextToText, true),
    ];
    let mut speedups = vec![];
    for (label, task, use_7b) in rows {
        let spec = if use_7b {
            common::task_spec_7b(task, 1)
        } else {
            common::task_spec(task, 1)
        };
        let base = task_cost(&spec, &A100, &Levers::baseline()).total;
        let ls = task_cost(
            &spec,
            &A100,
            &Levers { layerskip: true, ..Levers::baseline() },
        )
        .total;
        println!("  {:<20} {:.2}x", label, base / ls);
        speedups.push(base / ls);
    }
    println!(
        "geomean LayerSkip alone: {:.2}x (paper: 1.58x)\n\
         analytic speedup @accept={LAYERSKIP_ACCEPT}: 7B {:.2}x, 34B \
         {:.2}x, CM3-7B {:.2}x",
        geomean(&speedups),
        layerskip_speedup(&LLAMA_7B, LAYERSKIP_ACCEPT),
        layerskip_speedup(&LLAMA_34B, LAYERSKIP_ACCEPT),
        layerskip_speedup(&CHAMELEON_7B, LAYERSKIP_ACCEPT),
    );

    // "Putting it altogether": all levers vs baseline across the
    // decoder tasks (the 3.88x headline).
    let mut all = vec![];
    for task in [TaskKind::TextToText, TaskKind::ImageToText,
                 TaskKind::ImageTextToText, TaskKind::TextToImage] {
        let spec = common::task_spec(task, 1);
        let base = task_cost(&spec, &A100, &Levers::baseline()).total;
        let opt = task_cost(&spec, &A100, &Levers::all()).total;
        all.push(base / opt);
        println!("  all-levers {:<6} {:.2}x", task.notation(), base / opt);
    }
    println!(
        "geomean cross-stack (system + LayerSkip): {:.2}x \
         (paper: 3.88x)",
        geomean(&all)
    );
}

fn real_cpu_part() {
    let Some(dir) = common::artifacts_available() else { return };
    println!("\n=== LayerSkip (real CPU, tiny Llama): draft E=2/L=4, \
              verify K=4, greedy acceptance ===");
    let engine = Engine::load(&dir.join("llama")).expect("engine");
    let sp = SamplingParams::greedy();
    let prompt: Vec<i32> = (2..26).collect();
    let mut suite = BenchSuite::new("24-token generation");
    {
        let session =
            DecoderSession::new(&engine, OptConfig::baseline()).unwrap();
        let p = prompt.clone();
        suite.bench("autoregressive baseline", move || {
            session.generate(&p, 24, &sp).expect("gen");
        });
    }
    {
        let mut o = OptConfig::baseline();
        o.layerskip = true;
        let session = DecoderSession::new(&engine, o).unwrap();
        let p = prompt.clone();
        suite.bench("layerskip self-speculative", move || {
            session.generate(&p, 24, &sp).expect("gen");
        });
    }
    suite.speedup("layerskip vs baseline", "autoregressive baseline",
                  "layerskip self-speculative");
    // report acceptance
    let mut o = OptConfig::baseline();
    o.layerskip = true;
    let session = DecoderSession::new(&engine, o).unwrap();
    let r = session.generate(&prompt, 24, &sp).expect("gen");
    println!(
        "  acceptance: {}/{} drafts over {} rounds; outputs match \
         baseline greedy: {}",
        r.accepted_drafts,
        r.draft_rounds * 3,
        r.draft_rounds,
        {
            let b = DecoderSession::new(&engine, OptConfig::baseline())
                .unwrap()
                .generate(&prompt, 24, &sp)
                .unwrap();
            b.tokens == r.tokens
        }
    );
}
