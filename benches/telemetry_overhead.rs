//! Telemetry overhead smoke bench: the disabled-tracing path must be
//! indistinguishable from no tracing at all on the decode hot loop.
//!
//! Six regimes over the same synthetic inner loop:
//! * `no_tracer`      — the loop with no telemetry calls at all,
//! * `tracer_off`     — spans requested but tracing disabled (the
//!                      production default; one relaxed atomic load),
//! * `tracer_on`      — spans recorded (the cost you opt into),
//! * `live_off`       — live-registry publishes against a disabled
//!                      registry (must match the tracer_off contract:
//!                      one relaxed load, no lock, no allocation),
//! * `live_on`        — cached-handle publishes into an enabled
//!                      registry (counter bump + sketch bucket),
//! * `ledger_off`     — causal-ledger hooks against a disabled ledger
//!                      (same one-relaxed-load contract).

use mmserve::substrate::bench::{black_box, BenchSuite};
use mmserve::telemetry::ledger::{RequestLedger, TickCharges};
use mmserve::telemetry::live::LiveMetrics;
use mmserve::telemetry::tracer::{Cat, Tracer};

const ITERS: usize = 20_000;

/// Stand-in for the per-step host work of a decode loop.
fn step_work(i: usize) -> f64 {
    black_box((i as f64).sqrt().sin())
}

fn main() {
    let mut suite = BenchSuite::new(
        "telemetry overhead (20k synthetic decode steps)");

    let base = suite.bench("no_tracer", || {
        let mut acc = 0.0;
        for i in 0..ITERS {
            acc += step_work(i);
        }
        black_box(acc);
    });

    let off_tracer = Tracer::off();
    let off_wt = off_tracer.worker("bench");
    let off = suite.bench("tracer_off", || {
        let mut acc = 0.0;
        for i in 0..ITERS {
            let _g = off_wt.span(Cat::Sample, "step");
            acc += step_work(i);
        }
        black_box(acc);
    });
    assert_eq!(off_tracer.drain().len(), 0,
               "disabled tracer must record nothing");

    let on_tracer = Tracer::new();
    let on_wt = on_tracer.worker("bench");
    let on = suite.bench("tracer_on", || {
        let mut acc = 0.0;
        for i in 0..ITERS {
            let _g = on_wt.span(Cat::Sample, "step");
            acc += step_work(i);
        }
        black_box(acc);
    });
    let recorded = on_tracer.drain().len();
    assert!(recorded >= ITERS, "enabled tracer must record spans");

    let live_off = LiveMetrics::off();
    let off_live = suite.bench("live_off", || {
        let mut acc = 0.0;
        for i in 0..ITERS {
            live_off.inc("mmserve_ticks_total", &[("replica", "0")], 1);
            live_off.observe("mmserve_tbt_ms", &[("replica", "0")],
                             acc);
            acc += step_work(i);
        }
        black_box(acc);
    });
    let snap = live_off.snapshot();
    assert!(snap.counters.is_empty() && snap.sketches.is_empty(),
            "disabled live registry must not materialize series");
    // The disabled-mode gate: each publish is one relaxed atomic load.
    // 250 ns/op is ~50× that — generous against bench noise, but a
    // regression to lock-and-allocate-before-checking blows through it.
    let ns_per_pub =
        (off_live - base).max(0.0) * 1e9 / (ITERS as f64 * 2.0);
    assert!(
        ns_per_pub < 250.0,
        "disabled live-registry publish costs {ns_per_pub:.1} ns/op; \
         the one-relaxed-load gate is broken"
    );

    let live_on = LiveMetrics::new();
    let ticks = live_on.counter("mmserve_ticks_total",
                                &[("replica", "0")]);
    let tbt = live_on.sketch("mmserve_tbt_ms", &[("replica", "0")]);
    let on_live = suite.bench("live_on", || {
        let mut acc = 0.0;
        for i in 0..ITERS {
            ticks.inc(1);
            tbt.record(acc.abs() + 1.0);
            acc += step_work(i);
        }
        black_box(acc);
    });
    assert!(ticks.get() >= ITERS as u64,
            "enabled live registry must count");
    assert!(tbt.count() >= ITERS as u64,
            "enabled live registry must sketch");

    let ledger = RequestLedger::off();
    let ledger_off = suite.bench("ledger_off", || {
        let mut acc = 0.0;
        for i in 0..ITERS {
            ledger.decoded(7, i as f64, 1.0, 0.5);
            if ledger.is_enabled() {
                // The per-tick charge path behind the same gate the
                // serving loop uses (never taken here).
                ledger.charge_tick(&TickCharges {
                    dt: 1.0,
                    blocked_on_capacity: false,
                    waiting: &[],
                    prefill: &[],
                    pages: &[],
                });
            }
            acc += step_work(i);
        }
        black_box(acc);
    });
    assert!(ledger.snapshot().requests.is_empty(),
            "disabled ledger must record nothing");
    // Same disabled-mode gate as the live plane: one relaxed load per
    // would-be hook (decoded + the enabled check = 2 per iteration).
    let ledger_ns_per_hook =
        (ledger_off - base).max(0.0) * 1e9 / (ITERS as f64 * 2.0);
    assert!(
        ledger_ns_per_hook < 250.0,
        "disabled ledger hook costs {ledger_ns_per_hook:.1} ns/op; \
         the one-relaxed-load gate is broken"
    );

    println!(
        "\n  ledger per-hook cost: disabled {ledger_ns_per_hook:.1} ns",
    );
    println!(
        "\n  live plane per-publish cost: disabled {:.1} ns, \
         enabled (cached handles) {:.1} ns",
        ns_per_pub,
        (on_live - base).max(0.0) * 1e9 / (ITERS as f64 * 2.0)
    );
    println!(
        "\n  per-step cost: baseline {:.1} ns, disabled {:.1} ns, \
         enabled {:.1} ns ({} spans recorded)",
        base * 1e9 / ITERS as f64,
        off * 1e9 / ITERS as f64,
        on * 1e9 / ITERS as f64,
        recorded
    );
    suite.speedup("disabled-vs-baseline", "tracer_off", "no_tracer");
    println!("  disabled-mode overhead should be within noise of the \
              baseline; enabled mode pays one clock pair + buffer push \
              per span.");
}
