//! Telemetry overhead smoke bench: the disabled-tracing path must be
//! indistinguishable from no tracing at all on the decode hot loop.
//!
//! Three regimes over the same synthetic inner loop:
//! * `no_tracer`      — the loop with no telemetry calls at all,
//! * `tracer_off`     — spans requested but tracing disabled (the
//!                      production default; one relaxed atomic load),
//! * `tracer_on`      — spans recorded (the cost you opt into).

use mmserve::substrate::bench::{black_box, BenchSuite};
use mmserve::telemetry::tracer::{Cat, Tracer};

const ITERS: usize = 20_000;

/// Stand-in for the per-step host work of a decode loop.
fn step_work(i: usize) -> f64 {
    black_box((i as f64).sqrt().sin())
}

fn main() {
    let mut suite = BenchSuite::new(
        "telemetry overhead (20k synthetic decode steps)");

    let base = suite.bench("no_tracer", || {
        let mut acc = 0.0;
        for i in 0..ITERS {
            acc += step_work(i);
        }
        black_box(acc);
    });

    let off_tracer = Tracer::off();
    let off_wt = off_tracer.worker("bench");
    let off = suite.bench("tracer_off", || {
        let mut acc = 0.0;
        for i in 0..ITERS {
            let _g = off_wt.span(Cat::Sample, "step");
            acc += step_work(i);
        }
        black_box(acc);
    });
    assert_eq!(off_tracer.drain().len(), 0,
               "disabled tracer must record nothing");

    let on_tracer = Tracer::new();
    let on_wt = on_tracer.worker("bench");
    let on = suite.bench("tracer_on", || {
        let mut acc = 0.0;
        for i in 0..ITERS {
            let _g = on_wt.span(Cat::Sample, "step");
            acc += step_work(i);
        }
        black_box(acc);
    });
    let recorded = on_tracer.drain().len();
    assert!(recorded >= ITERS, "enabled tracer must record spans");

    println!(
        "\n  per-step cost: baseline {:.1} ns, disabled {:.1} ns, \
         enabled {:.1} ns ({} spans recorded)",
        base * 1e9 / ITERS as f64,
        off * 1e9 / ITERS as f64,
        on * 1e9 / ITERS as f64,
        recorded
    );
    suite.speedup("disabled-vs-baseline", "tracer_off", "no_tracer");
    println!("  disabled-mode overhead should be within noise of the \
              baseline; enabled mode pays one clock pair + buffer push \
              per span.");
}
