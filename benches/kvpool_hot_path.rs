//! kvpool hot-path microbench: the per-token and per-request costs the
//! paged pool adds to the serving loop.
//!
//! Regimes over the same request shapes:
//! * `dense_slots`    — the seed's `KvSlots` alloc/advance/release,
//!                      the baseline the pool must stay close to,
//! * `paged_cold`     — pool alloc/advance/release with an empty
//!                      prefix cache (every page fresh),
//! * `paged_shared`   — same traffic with a hot shared system prompt
//!                      (the prefix-cache fast path admission hits),
//! * `paged_churn`    — release-heavy traffic that keeps parking and
//!                      evicting cached prefixes (LRU pressure).
//!
//! CI runs this in test mode (`MMSERVE_BENCH_FAST=1`) so a hot-path
//! regression fails the gate, not just compile errors.

use mmserve::coordinator::kv::KvSlots;
use mmserve::kvpool::KvPool;
use mmserve::substrate::bench::{black_box, BenchSuite};

const REQUESTS: usize = 64;
const DECODE: usize = 32;
const PAGE: usize = 16;
const MAX_SEQ: usize = 512;

fn prompt(sys: &[i32], id: u64) -> Vec<i32> {
    let mut p = sys.to_vec();
    p.extend((0..12).map(|j| 1000 + id as i32 * 13 + j));
    p
}

fn main() {
    let mut suite =
        BenchSuite::new("kvpool hot path (64 requests × 32 decode steps)");
    let sys: Vec<i32> = (0..48).map(|i| i % 200).collect();

    suite.bench("dense_slots", || {
        let mut kv = KvSlots::new(8, MAX_SEQ);
        for id in 0..REQUESTS as u64 {
            let slot = kv.alloc(id, 60).unwrap();
            for _ in 0..DECODE {
                kv.advance(slot).unwrap();
            }
            kv.release(slot).unwrap();
        }
        black_box(kv.free_count());
    });

    suite.bench("paged_cold", || {
        // Fresh pool per iteration: no cache carry-over between
        // requests either (unique prompts).
        let mut pool = KvPool::new(64, PAGE, MAX_SEQ);
        for id in 0..REQUESTS as u64 {
            let p = prompt(&[], id);
            pool.alloc(id, &p).unwrap();
            for t in 0..DECODE {
                pool.advance(id, t as i32).unwrap();
            }
            pool.release(id).unwrap();
        }
        black_box(pool.stats.blocks_allocated);
    });

    let mut shared_hits = 0u64;
    suite.bench("paged_shared", || {
        let mut pool = KvPool::new(64, PAGE, MAX_SEQ);
        for id in 0..REQUESTS as u64 {
            let p = prompt(&sys, id);
            pool.alloc(id, &p).unwrap();
            for t in 0..DECODE {
                pool.advance(id, t as i32).unwrap();
            }
            pool.release(id).unwrap();
        }
        shared_hits = pool.stats.prefix_hits;
        black_box(pool.stats.prefix_hit_tokens);
    });
    assert!(shared_hits > 0, "shared system prompt must hit the cache");

    suite.bench("paged_churn", || {
        // A pool sized below the working set: every request evicts the
        // previous one's cached blocks.
        let mut pool = KvPool::new(8, PAGE, MAX_SEQ);
        for id in 0..REQUESTS as u64 {
            let p = prompt(&[], id);
            pool.alloc(id, &p).unwrap();
            for t in 0..DECODE {
                pool.advance(id, t as i32).unwrap();
            }
            pool.release(id).unwrap();
        }
        black_box(pool.stats.evictions);
    });

    suite.speedup("paged-vs-dense", "paged_cold", "dense_slots");
    println!(
        "  the pool's per-token cost must stay within a small factor of \
         the dense slot view; prefix sharing then buys admission \
         capacity the dense path cannot reach."
    );
}
