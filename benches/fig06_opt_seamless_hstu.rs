//! Figure 6: SDPA / +compile / +AutoQuant speedups for Seamless and
//! HSTU (device model), plus the real-CPU AutoQuant calibration (§4.2)
//! and HSTU fused-kernel measurement on the tiny models.

mod common;

use mmserve::coordinator::autoquant;
use mmserve::coordinator::hstu_loop::{HstuAttn, HstuRunner};
use mmserve::models::TaskKind;
use mmserve::perfmodel::device::A100;
use mmserve::perfmodel::latency::task_cost;
use mmserve::perfmodel::levers::Levers;
use mmserve::runtime::engine::Engine;
use mmserve::substrate::bench::{geomean, BenchSuite};
use mmserve::substrate::table::Table;
use mmserve::workload::hstu_histories;

fn main() {
    device_model_part();
    real_autoquant();
    real_hstu();
}

fn device_model_part() {
    println!("=== Figure 6 (device model): lever speedups, Seamless & \
              HSTU + AutoQuant for decoders, A100 ===");
    let tasks = [TaskKind::SpeechToSpeech, TaskKind::SpeechToText,
                 TaskKind::TextToTextTrans, TaskKind::TextToSpeech,
                 TaskKind::HistoryToAction];
    let mut t = Table::new(&["task", "batch", "sdpa", "sdpa+compile"]);
    for task in tasks {
        for batch in [1usize, common::paper_max_batch(task)] {
            let spec = common::task_spec(task, batch);
            let base = task_cost(&spec, &A100, &Levers::baseline()).total;
            let sdpa = task_cost(&spec, &A100, &Levers::sdpa()).total;
            let cmp = task_cost(&spec, &A100, &Levers::sdpa_compile()).total;
            t.row(&[
                task.notation().to_string(),
                format!("{batch}"),
                format!("{:.2}x", base / sdpa),
                format!("{:.2}x", base / cmp),
            ]);
        }
    }
    t.print();

    // AutoQuant on the decoder models (paper: +1.20–1.57x on top of
    // compile for single batch; 2.13x/4.38x total).
    println!("\nAutoQuant (decoders):");
    let mut totals = vec![];
    for task in [TaskKind::TextToText, TaskKind::ImageToText,
                 TaskKind::TextToImage, TaskKind::ImageTextToText] {
        for batch in [1usize, common::paper_max_batch(task)] {
            let spec = common::task_spec(task, batch);
            let base = task_cost(&spec, &A100, &Levers::baseline()).total;
            let cmp = task_cost(&spec, &A100, &Levers::sdpa_compile()).total;
            let opt = task_cost(&spec, &A100, &Levers::sys_opt()).total;
            println!(
                "  {:<6} bs={batch:<3}  +autoquant {:.2}x on top of \
                 compile; total {:.2}x over baseline",
                task.notation(),
                cmp / opt,
                base / opt
            );
            totals.push(base / opt);
        }
    }
    println!(
        "geomean total (sys-opt over baseline): {:.2}x  \
         (paper avg: 2.13x bs=1 / 4.38x max batch)",
        geomean(&totals)
    );
    // HSTU SDPA headline (paper: 2.11x bs=1, 9.87x max batch)
    let h1 = common::task_spec(TaskKind::HistoryToAction, 1);
    let hx = common::task_spec(TaskKind::HistoryToAction, 32);
    let s1 = task_cost(&h1, &A100, &Levers::baseline()).total
        / task_cost(&h1, &A100, &Levers::sdpa()).total;
    let sx = task_cost(&hx, &A100, &Levers::baseline()).total
        / task_cost(&hx, &A100, &Levers::sdpa()).total;
    println!(
        "HSTU fused-attention speedup: bs=1 {s1:.2}x, bs=32 {sx:.2}x \
         (paper: 2.11x / 9.87x)"
    );
}

fn real_autoquant() {
    let Some(dir) = common::artifacts_available() else { return };
    println!("\n=== §4.2 AutoQuant calibration (real CPU, tiny Llama) ===");
    let engine = Engine::load(&dir.join("llama")).expect("engine");
    let iters = if std::env::var("MMSERVE_BENCH_FAST").is_ok() { 5 } else { 30 };
    let rep = autoquant::calibrate_decode(&engine, iters).expect("calibrate");
    for t in &rep.timings {
        println!("  {:<24} {:>9.3} ms/step", t.stage, t.mean_s * 1e3);
    }
    println!("  chosen: {:?}", rep.chosen);
}

fn real_hstu() {
    let Some(dir) = common::artifacts_available() else { return };
    println!("\n=== HSTU naive vs fused Pallas kernel (real CPU, tiny) ===");
    let engine = Engine::load(&dir.join("hstu")).expect("engine");
    let histories = hstu_histories(8, 256, 3);
    let mut suite = BenchSuite::new("hstu forward s256 b8");
    for (label, attn) in [("naive", HstuAttn::Naive),
                          ("fused(pallas)", HstuAttn::Fused)] {
        let runner = HstuRunner::new(&engine, attn).expect("runner");
        let hs = histories.clone();
        suite.bench(label, move || {
            let r = runner.run_batch(&hs, 4, 5).expect("run");
            assert_eq!(r.len(), 8);
        });
    }
    suite.speedup("fused vs naive (interpret-mode CPU; real-TPU gain \
                   estimated in DESIGN.md)", "naive", "fused(pallas)");
}
