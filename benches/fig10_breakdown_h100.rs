//! Figure 10: operator time breakdown on H100, and the per-category
//! A100→H100 speedups (paper: Linear 6.82x, Attention 1.44x, ~1.68x
//! end-to-end at bs=1).

use mmserve::perfmodel::breakdown::{render, CATEGORIES};
use mmserve::perfmodel::device::{A100, H100};
use mmserve::perfmodel::levers::Levers;
use mmserve::perfmodel::standard_breakdown_rows;

fn main() {
    println!("=== Figure 10: operator breakdown on H100 (baseline) ===");
    let h100 = standard_breakdown_rows(&H100, &Levers::baseline());
    println!("{}", render(&h100));

    println!("A100 → H100 per-category speedups (decode phases):");
    let a100 = standard_breakdown_rows(&A100, &Levers::baseline());
    let mut e2e_a = 0.0;
    let mut e2e_h = 0.0;
    for (ra, rh) in a100.iter().zip(&h100) {
        e2e_a += ra.total;
        e2e_h += rh.total;
        let (pa, ta) = ra.phase_times.last().unwrap();
        let (_, th) = rh.phase_times.last().unwrap();
        let mut parts = vec![];
        for cat in CATEGORIES {
            let a = ta.get(cat);
            let h = th.get(cat);
            if a > 0.0 && h > 0.0 {
                parts.push(format!("{cat} {:.2}x", a / h));
            }
        }
        println!("  {:<22} [{pa}] {}", ra.label, parts.join(", "));
    }
    println!(
        "\nend-to-end A100/H100 (task-set total): {:.2}x \
         (paper: 1.68x at bs=1; Linear up to 6.82x, Attention 1.44x)",
        e2e_a / e2e_h
    );
    println!("paper shape check: Linear accelerates most (tensor-core \
              ratio), shifting bottlenecks toward Attention/Misc.");
}
