//! Figure 11: optimization speedups on H100 — and the diminishing-
//! returns comparison vs A100 (paper §4.5).

mod common;

use mmserve::models::TaskKind;
use mmserve::perfmodel::device::{A100, H100};
use mmserve::perfmodel::latency::task_cost;
use mmserve::perfmodel::levers::Levers;
use mmserve::substrate::table::Table;

fn main() {
    println!("=== Figure 11: lever speedups on H100 vs A100 (bs=1) ===");
    let rows = [
        ("Llama-34B T-T", TaskKind::TextToText, Levers::sys_opt()),
        ("Chameleon I-T", TaskKind::ImageToText, Levers::sys_opt()),
        ("Seamless S-S", TaskKind::SpeechToSpeech, Levers::sdpa_compile()),
        ("HSTU H-A", TaskKind::HistoryToAction, Levers::sdpa()),
    ];
    let mut t = Table::new(&[
        "workload", "A100 sys-opt", "H100 sys-opt", "A100 +layerskip",
        "H100 +layerskip",
    ]);
    for (label, task, lv) in rows {
        let spec = common::task_spec(task, 1);
        let mut ls = lv;
        ls.layerskip = matches!(
            task,
            TaskKind::TextToText | TaskKind::ImageToText
                | TaskKind::ImageTextToText
        );
        let su = |dev, l: &Levers| {
            task_cost(&spec, dev, &Levers::baseline()).total
                / task_cost(&spec, dev, l).total
        };
        t.row(&[
            label.to_string(),
            format!("{:.2}x", su(&A100, &lv)),
            format!("{:.2}x", su(&H100, &lv)),
            format!("{:.2}x", su(&A100, &ls)),
            format!("{:.2}x", su(&H100, &ls)),
        ]);
    }
    t.print();
    println!(
        "\npaper: H100 sys-opt 2.21x/3.1x/1.5x/2.7x (Llama/Chameleon/\
         Seamless/HSTU); software gains shrink on H100 because the \
         baseline hardware is stronger (diminishing returns, §4.5)."
    );
}
