#!/usr/bin/env python3
"""Markdown link checker (stdlib only) for the docs gate.

Usage: check_links.py PATH [PATH ...]

Each PATH is a markdown file or a directory (searched recursively for
*.md). For every inline link or image ``[text](target)``:

* ``http(s)://`` / ``mailto:`` targets are skipped (no network in CI);
* relative targets must exist on disk, resolved against the file;
* ``#fragment`` parts (including fragment-only links) must match a
  GitHub-style heading anchor in the target markdown file.

Exit code 1 with one line per broken link; 0 when everything resolves.
"""
import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"^(```|~~~)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")


def strip_fences(text):
    """Drop fenced code blocks so diagrams never look like links."""
    out, fenced = [], False
    for line in text.splitlines():
        if FENCE_RE.match(line.strip()):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


def github_slug(heading):
    """GitHub's heading → anchor rule: lowercase, drop punctuation,
    spaces become hyphens (backticks contribute their text)."""
    h = heading.strip().lower()
    h = re.sub(r"`([^`]*)`", r"\1", h)
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)  # linked headings
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(path, cache={}):
    if path not in cache:
        try:
            text = path.read_text(encoding="utf-8")
        except OSError:
            cache[path] = set()
        else:
            cache[path] = {
                github_slug(m.group(1))
                for m in (
                    HEADING_RE.match(line)
                    for line in strip_fences(text).splitlines()
                )
                if m
            }
    return cache[path]


def check_file(md, errors):
    text = strip_fences(md.read_text(encoding="utf-8"))
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, fragment = target.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if path_part and not dest.exists():
            errors.append(f"{md}: broken link -> {target}")
            continue
        if fragment:
            if dest.suffix != ".md" or dest.is_dir():
                continue  # anchors into non-markdown: out of scope
            if fragment not in anchors_of(dest):
                errors.append(f"{md}: missing anchor -> {target}")


def main(argv):
    if not argv:
        print(__doc__.strip())
        return 2
    files = []
    for arg in argv:
        p = Path(arg)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"check_links: no such path: {arg}")
            return 2
    errors = []
    for md in files:
        check_file(md, errors)
    for e in errors:
        print(e)
    print(
        f"check_links: {len(files)} file(s), "
        f"{len(errors)} broken link(s)"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
