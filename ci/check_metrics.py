#!/usr/bin/env python3
"""CI gate for the live-metrics Prometheus exposition.

Reads METRICS.prom (written by `mmserve stats --metrics-out`) and
hard-fails — same contract as check_perf.py, nothing is silently
skipped — unless:

1. Every required metric is present with the expected `# TYPE`
   (counter / gauge / summary). A metric the sampler stops publishing
   vanishes from dashboards and alerts without tripping any test;
   this gate is what trips.

2. Every sample of a required metric carries exactly the required
   label keys (e.g. `mmserve_live_pages{replica,shard}`): a renamed
   or dropped label silently forks the time series under scrape.

3. Every summary ships its `quantile` samples plus the `_sum` /
   `_count` pair, and every sample value parses as a finite float
   (counters additionally non-negative).

4. The run actually produced signal: ticks were published, requests
   completed, and the TTFT sketch is non-empty. A wiring regression
   that leaves the registry attached-but-unfed renders as all-zero
   series — presence checks alone would pass it.
"""

import math
import sys

EXPOSITION = sys.argv[1] if len(sys.argv) > 1 else "METRICS.prom"

# name -> (type, required label keys). Summary samples may also carry
# the reserved `quantile` label; it is not part of the series schema.
REQUIRED = {
    "mmserve_ticks_total": ("counter", {"replica"}),
    "mmserve_prefix_lookups_total": ("counter", {"replica"}),
    "mmserve_prefix_hits_total": ("counter", {"replica"}),
    "mmserve_capacity_wait_ticks_total": ("counter", {"replica"}),
    "mmserve_preemptions_total": ("counter", {"replica"}),
    "mmserve_evictions_total": ("counter", {"replica"}),
    "mmserve_shard_spills_total": ("counter", {"replica"}),
    "mmserve_requests_completed_total": ("counter", {"replica"}),
    "mmserve_tokens_decoded_total": ("counter", {"replica"}),
    "mmserve_enqueued_total": ("counter", {"replica"}),
    "mmserve_admitted_total": ("counter", {"replica"}),
    "mmserve_queue_depth": ("gauge", {"replica"}),
    "mmserve_prefix_hit_rate": ("gauge", {"replica"}),
    "mmserve_live_pages": ("gauge", {"replica", "shard"}),
    "mmserve_free_pages": ("gauge", {"replica", "shard"}),
    "mmserve_cached_pages": ("gauge", {"replica", "shard"}),
    "mmserve_ttft_ms": ("summary", {"replica", "tenant"}),
    "mmserve_tbt_ms": ("summary", {"replica", "tenant"}),
}


def parse_labels(body):
    """`k1="v1",k2="v2"` -> dict (values may contain escapes)."""
    labels = {}
    i = 0
    while i < len(body):
        eq = body.index("=", i)
        key = body[i:eq]
        assert body[eq + 1] == '"', body
        j = eq + 2
        val = []
        while body[j] != '"':
            if body[j] == "\\":
                j += 1
            val.append(body[j])
            j += 1
        labels[key] = "".join(val)
        i = j + 1
        if i < len(body) and body[i] == ",":
            i += 1
    return labels


def parse(text):
    """-> (types: name->kind, samples: name->[(labels, value)])."""
    types, samples = {}, {}
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split()
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        metric, _, value = line.rpartition(" ")
        if "{" in metric:
            name, _, rest = metric.partition("{")
            labels = parse_labels(rest.rstrip("}"))
        else:
            name, labels = metric, {}
        samples.setdefault(name, []).append((labels, float(value)))
    return types, samples


def main():
    failures = []
    try:
        with open(EXPOSITION) as f:
            text = f.read()
    except OSError as e:
        print(f"::error::cannot read {EXPOSITION}: {e}")
        sys.exit(1)

    try:
        types, samples = parse(text)
    except (AssertionError, ValueError, IndexError) as e:
        print(f"::error::{EXPOSITION} is not valid Prometheus "
              f"text exposition: {e!r}")
        sys.exit(1)

    for name, (kind, keys) in sorted(REQUIRED.items()):
        if types.get(name) != kind:
            failures.append(
                f"{name}: expected `# TYPE {name} {kind}`, "
                f"got {types.get(name)!r}")
            continue
        rows = samples.get(name, [])
        if not rows:
            failures.append(f"{name}: no samples")
            continue
        for labels, value in rows:
            got = set(labels) - {"quantile"}
            if got != keys:
                failures.append(
                    f"{name}: label schema {sorted(got)} != "
                    f"required {sorted(keys)}")
                break
            if not math.isfinite(value):
                failures.append(f"{name}: non-finite sample {value}")
                break
            if kind == "counter" and value < 0:
                failures.append(f"{name}: negative counter {value}")
                break
        if kind == "summary":
            for suffix in ("_sum", "_count"):
                if not samples.get(name + suffix):
                    failures.append(f"{name}: missing {name}{suffix}")

    def total(name):
        return sum(v for _, v in samples.get(name, []))

    if not failures:
        if total("mmserve_ticks_total") <= 0:
            failures.append("mmserve_ticks_total: no ticks published "
                            "(sampler not wired?)")
        if total("mmserve_requests_completed_total") <= 0:
            failures.append("mmserve_requests_completed_total: zero — "
                            "the replay completed nothing")
        if total("mmserve_ttft_ms_count") <= 0:
            failures.append("mmserve_ttft_ms: empty sketch — TTFT "
                            "observation not wired")

    if failures:
        for f_ in failures:
            print(f"::error::{f_}")
        sys.exit(1)

    n_series = sum(len(v) for v in samples.values())
    print(f"metrics gate ok: {len(REQUIRED)} required metrics, "
          f"{n_series} sample lines, "
          f"{int(total('mmserve_ticks_total'))} ticks, "
          f"{int(total('mmserve_requests_completed_total'))} requests")


if __name__ == "__main__":
    main()
