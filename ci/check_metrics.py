#!/usr/bin/env python3
"""CI gate for the live-metrics Prometheus exposition.

Reads one or two expositions (written by `mmserve stats
--metrics-out`) and hard-fails — same contract as check_perf.py,
nothing is silently skipped — unless:

1. Every required metric is present with the expected `# TYPE`
   (counter / gauge / summary). A metric the sampler stops publishing
   vanishes from dashboards and alerts without tripping any test;
   this gate is what trips.

2. Every sample of a required metric carries exactly the required
   label keys (e.g. `mmserve_live_pages{replica,shard}`): a renamed
   or dropped label silently forks the time series under scrape.

3. Every summary ships its `quantile` samples plus the `_sum` /
   `_count` pair, and every sample value parses as a finite float
   (counters additionally non-negative).

4. The run actually produced signal: ticks were published, requests
   completed, and the TTFT sketch is non-empty. A wiring regression
   that leaves the registry attached-but-unfed renders as all-zero
   series — presence checks alone would pass it.

Two-snapshot mode (`check_metrics.py SMALLER.prom BIGGER.prom`):
both files are fully validated, then the cumulative series are
checked for per-label-set monotonicity. The snapshots come from two
seeded replays of the same workload prefix (the second run replays a
superset of the first run's requests), so every counter and summary
`_sum`/`_count` series that counts delivered work must be >= its
smaller-run value under the same label set, and no label set may
vanish. A counter that resets — or a series that silently changes
its labels between runs — trips here. Only work-proportional series
are compared: tick/preemption/spill totals also depend on how the
smaller run drains after its last arrival, so they are not
prefix-comparable.
"""

import math
import sys

# name -> (type, required label keys). Summary samples may also carry
# the reserved `quantile` label; it is not part of the series schema.
REQUIRED = {
    "mmserve_ticks_total": ("counter", {"replica"}),
    "mmserve_prefix_lookups_total": ("counter", {"replica"}),
    "mmserve_prefix_hits_total": ("counter", {"replica"}),
    "mmserve_capacity_wait_ticks_total": ("counter", {"replica"}),
    "mmserve_preemptions_total": ("counter", {"replica"}),
    "mmserve_evictions_total": ("counter", {"replica"}),
    "mmserve_shard_spills_total": ("counter", {"replica"}),
    "mmserve_requests_completed_total": ("counter", {"replica"}),
    "mmserve_tokens_decoded_total": ("counter", {"replica"}),
    "mmserve_enqueued_total": ("counter", {"replica"}),
    "mmserve_admitted_total": ("counter", {"replica"}),
    "mmserve_queue_depth": ("gauge", {"replica"}),
    "mmserve_prefix_hit_rate": ("gauge", {"replica"}),
    "mmserve_live_pages": ("gauge", {"replica", "shard"}),
    "mmserve_free_pages": ("gauge", {"replica", "shard"}),
    "mmserve_cached_pages": ("gauge", {"replica", "shard"}),
    "mmserve_ttft_ms": ("summary", {"replica", "tenant"}),
    "mmserve_tbt_ms": ("summary", {"replica", "tenant"}),
}

# Cumulative series that grow with delivered work: when the second
# snapshot replays a superset of the first run's requests, each of
# these must be monotone per label set. (Ticks, preemptions, spills
# and capacity waits also accumulate during the smaller run's drain
# phase, so they are not comparable between different-length runs.)
MONOTONE = [
    "mmserve_enqueued_total",
    "mmserve_admitted_total",
    "mmserve_requests_completed_total",
    "mmserve_tokens_decoded_total",
    "mmserve_ttft_ms_count",
    "mmserve_ttft_ms_sum",
    "mmserve_tbt_ms_count",
    "mmserve_tbt_ms_sum",
]


def parse_labels(body):
    """`k1="v1",k2="v2"` -> dict (values may contain escapes)."""
    labels = {}
    i = 0
    while i < len(body):
        eq = body.index("=", i)
        key = body[i:eq]
        assert body[eq + 1] == '"', body
        j = eq + 2
        val = []
        while body[j] != '"':
            if body[j] == "\\":
                j += 1
            val.append(body[j])
            j += 1
        labels[key] = "".join(val)
        i = j + 1
        if i < len(body) and body[i] == ",":
            i += 1
    return labels


def parse(text):
    """-> (types: name->kind, samples: name->[(labels, value)])."""
    types, samples = {}, {}
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split()
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        metric, _, value = line.rpartition(" ")
        if "{" in metric:
            name, _, rest = metric.partition("{")
            labels = parse_labels(rest.rstrip("}"))
        else:
            name, labels = metric, {}
        samples.setdefault(name, []).append((labels, float(value)))
    return types, samples


def load(path):
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        print(f"::error::cannot read {path}: {e}")
        sys.exit(1)
    try:
        return parse(text)
    except (AssertionError, ValueError, IndexError) as e:
        print(f"::error::{path} is not valid Prometheus "
              f"text exposition: {e!r}")
        sys.exit(1)


def total(samples, name):
    return sum(v for _, v in samples.get(name, []))


def validate(path, types, samples):
    """Schema + signal checks for one exposition."""
    failures = []
    for name, (kind, keys) in sorted(REQUIRED.items()):
        if types.get(name) != kind:
            failures.append(
                f"{path}: {name}: expected `# TYPE {name} {kind}`, "
                f"got {types.get(name)!r}")
            continue
        rows = samples.get(name, [])
        if not rows:
            failures.append(f"{path}: {name}: no samples")
            continue
        for labels, value in rows:
            got = set(labels) - {"quantile"}
            if got != keys:
                failures.append(
                    f"{path}: {name}: label schema {sorted(got)} != "
                    f"required {sorted(keys)}")
                break
            if not math.isfinite(value):
                failures.append(
                    f"{path}: {name}: non-finite sample {value}")
                break
            if kind == "counter" and value < 0:
                failures.append(
                    f"{path}: {name}: negative counter {value}")
                break
        if kind == "summary":
            for suffix in ("_sum", "_count"):
                if not samples.get(name + suffix):
                    failures.append(
                        f"{path}: {name}: missing {name}{suffix}")

    if not failures:
        if total(samples, "mmserve_ticks_total") <= 0:
            failures.append(
                f"{path}: mmserve_ticks_total: no ticks published "
                "(sampler not wired?)")
        if total(samples, "mmserve_requests_completed_total") <= 0:
            failures.append(
                f"{path}: mmserve_requests_completed_total: zero — "
                "the replay completed nothing")
        if total(samples, "mmserve_ttft_ms_count") <= 0:
            failures.append(
                f"{path}: mmserve_ttft_ms: empty sketch — TTFT "
                "observation not wired")
    return failures


def series_map(samples, name):
    return {frozenset(l.items()): v for l, v in samples.get(name, [])}


def fmt_labels(labels):
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels))
    return "{" + inner + "}"


def check_monotone(smaller, bigger):
    """Per-label-set monotonicity of cumulative series."""
    pa, sa = smaller
    pb, sb = bigger
    failures = []
    for name in MONOTONE:
        older = series_map(sa, name)
        newer = series_map(sb, name)
        if not older:
            failures.append(
                f"{name}: no samples in {pa} to compare against")
            continue
        for labels, v1 in sorted(older.items(),
                                 key=lambda kv: sorted(kv[0])):
            v2 = newer.get(labels)
            pretty = f"{name}{fmt_labels(labels)}"
            if v2 is None:
                failures.append(
                    f"{pretty}: series present in {pa} but missing "
                    f"from {pb} (label set changed between runs?)")
            elif v2 < v1:
                failures.append(
                    f"{pretty}: not monotone over a superset replay: "
                    f"{pa} has {v1}, {pb} has {v2}")
    return failures


def main():
    paths = sys.argv[1:] or ["METRICS.prom"]
    if len(paths) > 2:
        print("::error::usage: check_metrics.py [EXPOSITION "
              "[BIGGER_EXPOSITION]]")
        sys.exit(2)

    snaps = [(p, *load(p)) for p in paths]
    failures = []
    for path, types, samples in snaps:
        failures += validate(path, types, samples)

    checked_monotone = 0
    if len(snaps) == 2 and not failures:
        mono = check_monotone(
            (snaps[0][0], snaps[0][2]), (snaps[1][0], snaps[1][2]))
        failures += mono
        checked_monotone = len(MONOTONE)

    if failures:
        for f_ in failures:
            print(f"::error::{f_}")
        sys.exit(1)

    for path, _, samples in snaps:
        n_series = sum(len(v) for v in samples.values())
        print(
            f"metrics gate ok: {path}: {len(REQUIRED)} required "
            f"metrics, {n_series} sample lines, "
            f"{int(total(samples, 'mmserve_ticks_total'))} ticks, "
            f"{int(total(samples, 'mmserve_requests_completed_total'))}"
            " requests")
    if checked_monotone:
        print(f"monotonicity ok: {checked_monotone} cumulative series "
              f"checked across {paths[0]} -> {paths[1]}")


if __name__ == "__main__":
    main()
