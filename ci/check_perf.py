#!/usr/bin/env python3
"""CI perf gate for the deterministic replay benchmarks.

Reads BENCH_kvpool.json and BENCH_routing.json (written by
`mmserve kv --bench-json`), BENCH_stats.json (written by
`mmserve stats --bench-json`), BENCH_explain.json (written by
`mmserve explain --bench-json`), BENCH_fabric.json (written by
`mmserve kv --disaggregate --fabric-json`), and BENCH_autoscale.json
(written by `mmserve kv --arrivals ... --autoscale --autoscale-json`)
and checks them three ways:

1. Hard invariants that must hold on any commit:
   - no replayed request is dropped (monolithic, sharded, or routed),
   - the paged pool actually shares prefixes (hit rate > 0),
   - prefix-affinity routing achieves a strictly higher aggregate
     prefix hit rate than round-robin,
   - the sharded replay completes exactly what the monolithic one does
     (page placement must never change workload outcomes),
   - attaching the live metrics plane leaves the simulated clock
     bit-identical (observation must never change scheduling),
   - attaching the causal cost ledger leaves the simulated clock
     bit-identical (same pure-observation contract),
   - disaggregated prefill/decode improves decode-worker TBT p99 over
     colocated at equal replica count, while the KV handoff stays
     explicitly priced (non-zero transfer bytes and link utilization),
   - on the open-loop diurnal+burst stream the autoscaled fleet drops
     nothing, serves every arrival, actually scales (>= 1 scale-up and
     >= 1 drain), beats the fixed-min fleet on burst-phase p99 TTFT,
     pays strictly fewer replica-seconds than the fixed-max fleet, and
     keeps goodput per replica-second within tolerance of fixed-max.

2. Required schema: every metric path listed under "schema" in
   ci/perf-baseline.json must exist in the fresh bench output. A
   metric the CLI stops emitting — or a bench section that silently
   disappears (e.g. the sharded replay) — is a HARD FAILURE, not a
   skipped gate. Gates referencing files or paths the run did not
   produce fail the same way; nothing is silently ignored.

3. Baseline regression gates from ci/perf-baseline.json: each gate
   names a metric path, a direction, and the committed baseline value;
   the job fails when the current value regresses past the tolerance
   (default 10%). The replays are seeded and run on a simulated clock,
   so values are bit-identical across machines — a tripped gate means
   the *code* changed behavior, not the runner.

Refreshing the baseline after an intentional change: download the
bench-replay-metrics artifact from the Actions run and copy the new
values into ci/perf-baseline.json in the same PR.
"""

import json
import sys

BASELINE = "ci/perf-baseline.json"


def dig(doc, path):
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def main():
    failures = []
    notices = []

    kv = json.load(open("BENCH_kvpool.json"))
    rt = json.load(open("BENCH_routing.json"))
    st = json.load(open("BENCH_stats.json"))
    ex = json.load(open("BENCH_explain.json"))
    fb = json.load(open("BENCH_fabric.json"))
    au = json.load(open("BENCH_autoscale.json"))
    docs = {
        "BENCH_kvpool.json": kv,
        "BENCH_routing.json": rt,
        "BENCH_stats.json": st,
        "BENCH_explain.json": ex,
        "BENCH_fabric.json": fb,
        "BENCH_autoscale.json": au,
    }

    # ---- hard invariants -------------------------------------------
    if dig(kv, "kvpool.paged.dropped") != 0:
        failures.append("kvpool replay dropped requests")
    if (dig(kv, "kvpool.paged.hit_rate") or 0) <= 0:
        failures.append("kvpool replay has a zero prefix hit rate")
    if dig(kv, "kvpool.sharded") is not None:
        if dig(kv, "kvpool.sharded.dropped") != 0:
            failures.append("sharded kvpool replay dropped requests")
        if dig(kv, "kvpool.sharded.completed") != dig(
            kv, "kvpool.paged.completed"
        ):
            failures.append(
                "sharded replay completed a different request count "
                "than the monolithic replay on the same workload"
            )
    rr = dig(rt, "routing.policies.round-robin.agg_hit_rate")
    pa = dig(rt, "routing.policies.prefix-affinity.agg_hit_rate")
    if rr is None or pa is None:
        failures.append("routing policies missing from BENCH_routing.json")
    elif pa <= rr:
        failures.append(
            f"prefix-affinity hit rate {pa:.4f} does not beat "
            f"round-robin {rr:.4f}"
        )
    for policy in ("round-robin", "least-loaded", "prefix-affinity"):
        if dig(rt, f"routing.policies.{policy}.dropped") != 0:
            failures.append(f"routing replay ({policy}) dropped requests")
    # The live metrics plane is pure observation: the instrumented
    # replay's simulated clock must agree with the bare replay's
    # exactly (seeded, simulated — any delta means sampling changed
    # scheduling decisions).
    if dig(st, "live.sim_time_delta") != 0:
        failures.append(
            "live metrics plane changed replay outcomes "
            f"(sim_time_delta = {dig(st, 'live.sim_time_delta')!r})"
        )
    # Same contract for the causal cost ledger: pure observation.
    if dig(ex, "ledger.sim_time_delta") != 0:
        failures.append(
            "causal cost ledger changed replay outcomes "
            f"(sim_time_delta = {dig(ex, 'ledger.sim_time_delta')!r})"
        )
    if (dig(ex, "ledger.completed") or 0) <= 0:
        failures.append("ledger replay completed no requests")
    # Disaggregation A/B: the split must win the decode tail at equal
    # replica count, with the handoff cost genuinely priced — zero
    # transfer bytes would mean the fabric stopped charging.
    for arm in ("colocated", "disaggregated"):
        if dig(fb, f"fabric.{arm}.dropped") != 0:
            failures.append(f"fabric A/B ({arm}) dropped requests")
    if dig(fb, "fabric.disaggregated.completed") != dig(
        fb, "fabric.colocated.completed"
    ):
        failures.append(
            "disaggregated replay completed a different request count "
            "than the colocated replay on the same workload"
        )
    if (dig(fb, "fabric.deltas.p99_tbt_improvement") or 0) <= 0:
        failures.append(
            "disaggregated prefill/decode does not improve decode TBT "
            "p99 over colocated "
            f"(improvement = "
            f"{dig(fb, 'fabric.deltas.p99_tbt_improvement')!r})"
        )
    if (dig(fb, "fabric.disaggregated.transfer_bytes") or 0) <= 0:
        failures.append("disaggregated replay moved zero priced KV bytes")
    if (dig(fb, "fabric.disaggregated.link_utilization") or 0) <= 0:
        failures.append("disaggregated replay has zero link utilization")
    # Autoscale A/B on the open-loop diurnal+burst stream: all three
    # arms serve the identical timestamped arrivals, so drops and
    # unserved arrivals are scheduler bugs, not load shedding. The
    # elastic fleet must genuinely scale and must win both headline
    # tradeoffs it exists for: burst tail latency vs the fixed-min
    # fleet and paid capacity vs the fixed-max fleet.
    for arm in ("autoscaled", "fixed_min", "fixed_max"):
        if dig(au, f"autoscale.{arm}.dropped") != 0:
            failures.append(f"autoscale A/B ({arm}) dropped requests")
        if dig(au, f"autoscale.{arm}.completed") != dig(
            au, f"autoscale.{arm}.arrivals"
        ):
            failures.append(
                f"autoscale A/B ({arm}) left arrivals unserved "
                f"(completed {dig(au, f'autoscale.{arm}.completed')!r} "
                f"of {dig(au, f'autoscale.{arm}.arrivals')!r})"
            )
    if (dig(au, "autoscale.autoscaled.scale_ups") or 0) < 1:
        failures.append("autoscaled replay never scaled up on the burst")
    if (dig(au, "autoscale.autoscaled.drains") or 0) < 1:
        failures.append(
            "autoscaled replay never drained an idle replica"
        )
    if (dig(au, "autoscale.deltas.burst_p99_ttft_improvement") or 0) <= 0:
        failures.append(
            "autoscaled fleet does not beat the fixed-min fleet on "
            "burst-phase p99 TTFT (improvement = "
            f"{dig(au, 'autoscale.deltas.burst_p99_ttft_improvement')!r})"
        )
    if (dig(au, "autoscale.deltas.replica_seconds_saved") or 0) <= 0:
        failures.append(
            "autoscaled fleet does not pay fewer replica-seconds than "
            "the fixed-max fleet (saved = "
            f"{dig(au, 'autoscale.deltas.replica_seconds_saved')!r})"
        )

    base = json.load(open(BASELINE))

    # Efficiency guard tied to the committed tolerance: the elastic
    # fleet may trade a little goodput-per-replica-second for its
    # capacity savings, but no more than the gate tolerance below the
    # always-on fixed-max fleet.
    ratio = dig(au, "autoscale.deltas.goodput_ratio_vs_max")
    if ratio is None or ratio < 1.0 - base.get("tolerance", 0.10):
        failures.append(
            "autoscaled goodput per replica-second fell more than the "
            f"tolerance below the fixed-max fleet (ratio = {ratio!r})"
        )

    # ---- required schema: missing keys are hard failures -----------
    for fname, paths in base.get("schema", {}).items():
        doc = docs.get(fname)
        if doc is None:
            failures.append(
                f"schema names {fname}, which this run did not produce"
            )
            continue
        for path in paths:
            if dig(doc, path) is None:
                failures.append(
                    f"{fname}:{path} missing from bench output "
                    f"(required by {BASELINE} schema)"
                )

    # ---- baseline regression gates ---------------------------------
    tol = base.get("tolerance", 0.10)
    for gate in base.get("gates", []):
        label = f"{gate['file']}:{gate['path']}"
        doc = docs.get(gate["file"])
        if doc is None:
            failures.append(
                f"{label}: gate references unknown bench file "
                f"{gate['file']!r}"
            )
            continue
        cur = dig(doc, gate["path"])
        if cur is None:
            failures.append(f"{label} missing from bench output")
            continue
        ref = gate.get("value")
        if ref is None:
            notices.append(
                f"{label} = {cur:.4f} (no baseline committed yet — "
                f"copy this value into {BASELINE})"
            )
            continue
        # A gate may carry a wider initial tolerance until its value
        # is pinned from a real artifact; drop the override (falling
        # back to the global 10%) when pinning.
        gtol = gate.get("tolerance", tol)
        if gate["direction"] == "min" and cur < ref * (1.0 - gtol):
            failures.append(
                f"{label} regressed: {cur:.4f} < baseline {ref:.4f} "
                f"- {gtol:.0%}"
            )
        elif gate["direction"] == "max" and cur > ref * (1.0 + gtol):
            failures.append(
                f"{label} regressed: {cur:.4f} > baseline {ref:.4f} "
                f"+ {gtol:.0%}"
            )
        else:
            print(f"ok: {label} = {cur:.4f} (baseline {ref:.4f}, "
                  f"{gate['direction']} ±{gtol:.0%})")

    for n in notices:
        print(f"::notice::{n}")
    if failures:
        for f in failures:
            print(f"::error::{f}")
        sys.exit(1)
    print("perf gate passed")


if __name__ == "__main__":
    main()
